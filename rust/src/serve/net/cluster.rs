//! Cluster frontend: the same submit/recv surface as a local server,
//! dispatched across remote shard nodes.
//!
//! A [`Cluster`] connects to N [`NodeServer`](super::node::NodeServer)
//! addresses and implements [`Dispatch`], so clients (and `serve_demo`,
//! and the CLI) cannot tell it from an in-process
//! [`GenServer`](crate::serve::GenServer):
//!
//! * **Placement** — each submit goes to the placeable shard with the
//!   least effective load: the queue depth it reported in its last
//!   heartbeat plus the slots this frontend has in flight to it
//!   (covering the window before the next heartbeat reflects them),
//!   inflated by the ramp-up handicap of freshly re-admitted shards.
//!   See [`Health::pick`].
//! * **Control plane** — unless [`ClusterOpts::control_plane`] is off,
//!   each shard gets *two* connections, tagged by a `Hello{role}`
//!   handshake: a data connection (submits out, responses back) and a
//!   control connection carrying only ping/pong/stats. Liveness is
//!   judged on the control connection, where a pong can never queue
//!   behind a multi-MiB response frame — a node that is merely *busy*
//!   is not a dead node. With the control plane off (the pre-isolation
//!   mode), heartbeats ride the data connection and depend on frame
//!   chunking alone to stay prompt.
//! * **Health** — a monitor thread pings every connected shard each
//!   heartbeat interval; a shard silent past half the timeout is
//!   deprioritized (Suspect), past the whole timeout — or on any
//!   connection error — declared dead. Death is *recoverable*: a
//!   reconnector thread re-dials dead shards every
//!   [`ClusterOpts::reconnect`], a revived shard re-enters as
//!   Probation (pinged, never placed), and after
//!   [`HealthPolicy::readmit_pongs`] consecutive pongs it is
//!   re-admitted with a decaying placement handicap so a flapping node
//!   cannot oscillate the scheduler. See [`super::health`].
//! * **Re-queue on node loss** — the in-flight requests of a dead
//!   shard are resubmitted to surviving shards (counted in
//!   [`ServerStats::requeued`]), reusing the same
//!   purge-and-repropagate semantics the router applies to a dead
//!   worker's batch. Only when *no* shard survives does a client see
//!   [`ServeError::NodeLost`] — otherwise node loss is invisible,
//!   modulo latency.
//! * **Stats** — shard nodes answer `StatsReq` (on the control
//!   connection) with live [`ServerStats`] snapshots; the cluster
//!   aggregates them via [`ServerStats::absorb`] (so the
//!   batcher-conservation identity `enqueued == dispatched + purged +
//!   pending` keeps holding over the sum) and overlays what only it
//!   can see: cluster-level request/failure counts, the *end-to-end*
//!   latency histogram (queue + wire + compute, measured at the
//!   frontend), re-queues, lost and re-admitted nodes.
//! * **Tracing** — each submit mints (or joins, via `submit_traced`) a
//!   [`TraceCtx`](crate::obs::trace::TraceCtx). Once a shard's data
//!   plane acknowledges [`WIRE_TRACE`], the pre-minted dispatch-hop
//!   span id rides the `Submit` and the node's spans for the request
//!   come home on the `Response`, where they are re-based into this
//!   process's timeline — one trace id stitches the frontend's
//!   request/dispatch spans and the node's queue/compute spans into a
//!   single timeline. A peer below the trace wire just sees untraced
//!   submits: the timeline keeps its frontend half and nothing breaks.
//!
//! Locking: the state mutex and the per-shard writer mutexes are never
//! held together — state decisions happen under the state lock, frame
//! writes after it is released — so a slow TCP write can not stall
//! submits, deliveries or the heartbeat monitor. Each shard carries a
//! connection *epoch*, bumped on every reconnect: a reader thread from
//! a previous connection reporting its death late cannot kill the
//! replacement.
//!
//! **Reactor mode** ([`ClusterOpts::reactor`]): the same protocol,
//! health machine and re-queue semantics, but the per-shard reader
//! threads and the monitor thread collapse into one
//! [`super::reactor::Reactor`]. Frames arrive as `Driver::on_message`
//! callbacks keyed by the shard/plane/epoch tag each registered
//! connection carries; the heartbeat + stall-probe + expiry sweep runs
//! as a reactor timer (the stall watermark reads the reactor's own
//! per-connection byte counter instead of a [`CountingReader`]); and
//! writes route through the reactor handle, pings and stats requests
//! on the ctrl-priority lane. Shard stats arrive as
//! [`Msg::StatsDelta`] pushes folded into the per-shard cumulative
//! snapshot instead of snapshot-on-request polling (the poll fallback
//! stays for nodes that push nothing). Blocking dials remain
//! quarantined on the reconnector thread, which hands connected
//! streams to the reactor instead of spawning readers.

use std::collections::HashMap;
use std::io::Read;
use std::net::{SocketAddr, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::obs::hist::LatencyHist;
use crate::obs::trace::{self, SpanKind, SpanRec, TraceCtx};
use crate::serve::dispatch::Dispatch;
use crate::serve::error::ServeError;
use crate::serve::net::health::{Health, HealthPolicy, ShardState};
use crate::serve::net::proto::{Msg, Role, WIRE_TRACE};
use crate::serve::net::reactor::{
    Ctl, Driver, Handle, Reactor, ReactorOpts, Token,
};
use crate::serve::net::wire::{write_frame, MessageReader, WireError};
use crate::serve::router::{
    GenRequest, GenResponse, GenResult, ServerStats,
};
use crate::{debug_log, warn_log};

/// Cluster tuning knobs.
#[derive(Clone, Copy, Debug)]
pub struct ClusterOpts {
    /// Heartbeat cadence + node-loss deadline + re-admission policy.
    pub health: HealthPolicy,
    /// Backpressure: reject submits once this many image slots are in
    /// flight across all shards (mirrors the router's queue cap).
    pub max_queue: usize,
    /// Give each shard a dedicated control connection for
    /// ping/pong/stats (`--control-plane`; on by default). Off =
    /// heartbeats share the data connection — the pre-isolation
    /// *topology*, for diagnosis and A/B-ing the fix. Note this is not
    /// a cross-version compatibility mode: both ends speak wire v2
    /// either way.
    pub control_plane: bool,
    /// How often the reconnector re-dials a dead shard
    /// (`--reconnect-ms`).
    pub reconnect: Duration,
    /// Drive every shard connection from one poll-based reactor
    /// thread instead of per-connection reader threads + a monitor
    /// thread (`--reactor`). Same protocol, health machine and
    /// re-queue semantics either way.
    pub reactor: bool,
}

impl Default for ClusterOpts {
    fn default() -> Self {
        ClusterOpts {
            health: HealthPolicy::default(),
            max_queue: 16384,
            control_plane: true,
            reconnect: Duration::from_millis(1000),
            reactor: false,
        }
    }
}

impl ClusterOpts {
    /// The one place the config's knobs become cluster options — the
    /// CLI, the demo and future callers must not each repeat this
    /// mapping.
    pub fn from_run_config(cfg: &crate::util::config::RunConfig)
                           -> ClusterOpts {
        ClusterOpts {
            health: HealthPolicy {
                heartbeat: Duration::from_millis(cfg.heartbeat_ms),
                timeout: Duration::from_millis(cfg.node_timeout_ms),
                readmit_pongs: cfg.readmit_pongs,
            },
            control_plane: cfg.control_plane,
            reconnect: Duration::from_millis(cfg.reconnect_ms),
            reactor: cfg.reactor,
            ..ClusterOpts::default()
        }
    }
}

/// One outstanding request (enough to resubmit it on node loss).
struct ClusterPending {
    class: i32,
    n: usize,
    tx: Sender<GenResult>,
    /// Shard currently responsible for it.
    shard: usize,
    t0: Instant,
    /// Root trace context ([`TraceCtx::NONE`] = untraced); `span` is
    /// the pre-minted request-root span id, recorded at completion.
    trace: TraceCtx,
    /// Span the request root itself parents under (a caller's span
    /// via `submit_traced`, 0 for a locally minted root).
    parent_span: u64,
    /// Submit time on the trace clock (0 when untraced).
    t0_ns: u64,
    /// Current dispatch hop: the pre-minted span id the node parents
    /// its spans under, and when the hop went on the wire. Re-minted
    /// when the request is re-homed off a dead shard.
    dispatch_span: u64,
    dispatch_t0_ns: u64,
}

struct ClusterState {
    open: bool,
    /// Deliberate teardown: connection drops are expected, not losses.
    closing: bool,
    health: Health,
    pending: HashMap<u64, ClusterPending>,
    /// Per-shard in-flight slot estimate (submitted minus answered).
    inflight: Vec<usize>,
    /// Per-shard connection epoch; bumped on every (re)connect. Loss
    /// reports carry the epoch they observed — stale ones are ignored.
    epoch: Vec<u64>,
    /// Last reconnect attempt per dead shard (`None` = retry now).
    last_reconnect: Vec<Option<Instant>>,
    /// Data-plane progress watermark per shard: the byte counter last
    /// observed and when it last *changed* (see the stall check in
    /// `monitor_loop`).
    data_progress: Vec<(u64, Instant)>,
    requests: u64,
    failed_requests: u64,
    requeued: u64,
    nodes_lost: u64,
    nodes_readmitted: u64,
    /// First recorded loss cause (attached to dead-cluster errors).
    first_cause: Option<String>,
    /// End-to-end latency of completed requests (queue + wire +
    /// compute, measured at the frontend).
    latency: LatencyHist,
    /// Wire feature level each shard's data plane acknowledged (0
    /// until its `HelloAck` lands; reset on reconnect). Trace ids go
    /// on the wire only at [`WIRE_TRACE`] and above.
    wire: Vec<u16>,
    /// Last stats snapshot + the request seq it answered, per shard.
    last_stats: Vec<Option<ServerStats>>,
    stats_seen: Vec<u64>,
    stats_want: u64,
    ping_seq: u64,
    /// Reactor mode: the live token per shard and plane (`None` =
    /// dead, or dialed but not yet through the reactor's `on_open`).
    data_token: Vec<Option<Token>>,
    ctrl_token: Vec<Option<Token>>,
    /// Reactor mode: the epoch whose delta stream last fed
    /// `last_stats[i]` — while it trails `epoch[i]`, the heartbeat
    /// polls full snapshots as a fallback (threaded nodes and the
    /// shared-connection topology push no deltas).
    delta_epoch: Vec<u64>,
}

/// One shard's write halves. `data` carries submits (and, with the
/// control plane off, heartbeats); `ctrl` carries only ping/stats.
/// `bulk` serializes multi-chunk messages on `data` — the frame lock
/// is released between chunks so small frames interleave. `None`
/// streams mean the shard is dead (or being torn down).
struct ShardConn {
    data: Mutex<Option<TcpStream>>,
    bulk: Mutex<()>,
    ctrl: Mutex<Option<TcpStream>>,
}

impl ShardConn {
    fn empty() -> ShardConn {
        ShardConn {
            data: Mutex::new(None),
            bulk: Mutex::new(()),
            ctrl: Mutex::new(None),
        }
    }

    /// Take + close both halves (node loss, teardown).
    fn close(&self) {
        for half in [&self.data, &self.ctrl] {
            let mut g = crate::util::lock(half);
            if let Some(s) = g.take() {
                let _ = s.shutdown(std::net::Shutdown::Both);
            }
        }
    }
}

struct ClusterShared {
    addrs: Vec<String>,
    conns: Vec<ShardConn>,
    /// Bytes ever read off each shard's *data* connection(s) —
    /// chunk-granular progress evidence for the stall check, bumped
    /// lock-free by the data reader's [`CountingReader`]. Monotonic
    /// across reconnects (only ever compared for change).
    data_bytes: Vec<Arc<AtomicU64>>,
    state: Mutex<ClusterState>,
    /// Signaled on delivery, node loss, stats arrival and teardown.
    changed: Condvar,
    /// Reader threads, spawned per (re)connect; reaped on teardown.
    readers: Mutex<Vec<JoinHandle<()>>>,
    /// Reactor mode: the cross-thread mailbox into the reactor, set
    /// once right after spawn (empty in threaded mode).
    reactor: OnceLock<Handle<ClusterTag>>,
    opts: ClusterOpts,
}

impl ClusterShared {
    fn lock(&self) -> std::sync::MutexGuard<'_, ClusterState> {
        crate::util::lock(&self.state)
    }
}

/// Handle to the cross-node generation service. `Sync` like the local
/// router: any number of client threads submit through one reference.
pub struct Cluster {
    shared: Arc<ClusterShared>,
    next_id: AtomicU64,
    monitor: Option<JoinHandle<()>>,
    reconnector: Option<JoinHandle<()>>,
    /// Reactor mode: the event loop to join on teardown.
    reactor: Option<Reactor>,
    t_start: Instant,
}

/// Isolating liveness on the control connection buys immunity to
/// busy-node false deaths, but loses PR 4's side effect that a
/// *data-path* fault broke the heartbeat too: a half-open data
/// connection (middlebox silently dropping its state) would otherwise
/// hang placed requests for the kernel's retransmission give-up
/// (~15 min) while control pongs keep the shard Alive. The monitor
/// therefore also pings the data plane each beat and watches
/// byte-level read progress: a shard with work in flight whose data
/// connection moves **zero bytes** for this long is declared lost.
/// The deadline is deliberately lenient — pongs interleave between
/// chunks, so even multi-MiB streams move bytes constantly; only a
/// genuinely wedged path trips it — and floored at 30 s so a slow
/// frame parse can never mimic a stall.
fn data_stall_deadline(timeout: Duration) -> Duration {
    (timeout * 10).max(Duration::from_secs(30))
}

/// Read adapter counting every byte pulled off a data connection —
/// chunk-granular progress evidence (a reader mid-reassembly of a
/// huge response still advances it, where message-level bookkeeping
/// would sit still).
struct CountingReader {
    inner: TcpStream,
    bytes: Arc<AtomicU64>,
}

impl Read for CountingReader {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        let n = self.inner.read(buf)?;
        self.bytes.fetch_add(n as u64, Ordering::Relaxed);
        Ok(n)
    }
}

/// Dial one connection to a shard and tag its role. The connect is
/// bounded by the liveness deadline — a black-holed address (firewall
/// swallowing SYNs) must not wedge the reconnector for the OS connect
/// timeout, which teardown would then wait out joining it — and the
/// write timeout keeps a peer that stops *reading* from wedging the
/// writer locks (which would also stall the heartbeat monitor).
fn dial(addr: &str, role: Role, deadline: Duration)
        -> std::io::Result<TcpStream> {
    use std::net::ToSocketAddrs;
    // try every resolved address like `TcpStream::connect` does (a
    // dual-stack hostname may listen on one family only), each
    // attempt individually bounded
    let mut found = None;
    let mut last_err = None;
    for target in addr.to_socket_addrs()? {
        match TcpStream::connect_timeout(&target, deadline) {
            Ok(s) => {
                found = Some(s);
                break;
            }
            Err(e) => last_err = Some(e),
        }
    }
    let Some(mut stream) = found else {
        return Err(last_err.unwrap_or_else(|| {
            std::io::Error::new(
                std::io::ErrorKind::AddrNotAvailable,
                format!("{addr}: no resolvable address"),
            )
        }));
    };
    let _ = stream.set_nodelay(true);
    let _ = stream.set_write_timeout(Some(deadline));
    // advertise the full feature level (binary responses + trace
    // fields): `Msg::decode` routes marked payloads on any reader,
    // and trace ids are only *sent* once the ack confirms the level
    let hello = Msg::Hello { role, max_wire: WIRE_TRACE };
    write_frame(&mut stream, &hello.encode()).map_err(
        |e| std::io::Error::new(std::io::ErrorKind::BrokenPipe,
                                e.to_string()),
    )?;
    Ok(stream)
}

/// Dial a shard's full connection set: data always, control unless
/// disabled. Returns the write halves ready to install.
fn dial_shard(addr: &str, opts: &ClusterOpts)
              -> std::io::Result<(TcpStream, Option<TcpStream>)> {
    let data = dial(addr, Role::Data, opts.health.timeout)?;
    let ctrl = if opts.control_plane {
        Some(dial(addr, Role::Control, opts.health.timeout)?)
    } else {
        None
    };
    Ok((data, ctrl))
}

impl Cluster {
    /// Connect to the shard nodes. Unreachable addresses start dead
    /// (logged) and are retried by the reconnector; at least one must
    /// be reachable up front or this errors.
    pub fn connect(addrs: &[String], opts: ClusterOpts) -> Result<Cluster> {
        if addrs.is_empty() {
            bail!("cluster needs at least one shard address");
        }
        let now = Instant::now();
        let mut health = Health::new(addrs.len(), opts.health, now);
        let mut conns = Vec::with_capacity(addrs.len());
        // (shard, read-half, plane) for the reader spawns below
        let mut reader_specs: Vec<(usize, TcpStream, Role)> = Vec::new();
        let mut epoch = vec![0u64; addrs.len()];
        let mut nodes_lost = 0u64;
        let mut first_cause = None;
        for (i, addr) in addrs.iter().enumerate() {
            let conn = ShardConn::empty();
            match dial_shard(addr, &opts).and_then(|(data, ctrl)| {
                if opts.reactor {
                    // the reactor owns each stream outright: no read
                    // clones, no write halves in `ShardConn`
                    return Ok((None, None, data, ctrl));
                }
                let data_rd = data.try_clone()?;
                let ctrl_rd = match &ctrl {
                    Some(c) => Some(c.try_clone()?),
                    None => None,
                };
                Ok((Some(data), ctrl, data_rd, ctrl_rd))
            }) {
                Ok((data_wr, ctrl_wr, data_rd, ctrl_rd)) => {
                    *crate::util::lock(&conn.data) = data_wr;
                    *crate::util::lock(&conn.ctrl) = ctrl_wr;
                    epoch[i] = 1;
                    reader_specs.push((i, data_rd, Role::Data));
                    if let Some(c) = ctrl_rd {
                        reader_specs.push((i, c, Role::Control));
                    }
                }
                Err(e) => {
                    warn_log!("cluster: shard {addr} unreachable: {e} \
                               (will keep retrying)");
                    health.mark_dead(i);
                    nodes_lost += 1;
                    first_cause
                        .get_or_insert(format!("shard {addr}: {e}"));
                }
            }
            conns.push(conn);
        }
        if health.serving_count() == 0 {
            bail!(
                "no shard node reachable ({})",
                first_cause.as_deref().unwrap_or("none configured")
            );
        }
        let n = addrs.len();
        let shared = Arc::new(ClusterShared {
            addrs: addrs.to_vec(),
            conns,
            data_bytes: (0..n)
                .map(|_| Arc::new(AtomicU64::new(0)))
                .collect(),
            state: Mutex::new(ClusterState {
                open: true,
                closing: false,
                health,
                pending: HashMap::new(),
                inflight: vec![0; n],
                epoch,
                last_reconnect: vec![None; n],
                data_progress: vec![(0, now); n],
                requests: 0,
                failed_requests: 0,
                requeued: 0,
                nodes_lost,
                nodes_readmitted: 0,
                first_cause,
                latency: LatencyHist::new(),
                wire: vec![0; n],
                last_stats: vec![None; n],
                stats_seen: vec![0; n],
                stats_want: 0,
                ping_seq: 0,
                data_token: vec![None; n],
                ctrl_token: vec![None; n],
                delta_epoch: vec![0; n],
            }),
            changed: Condvar::new(),
            readers: Mutex::new(Vec::new()),
            reactor: OnceLock::new(),
            opts,
        });
        // the reconnector runs in both modes: it is the one thread
        // blocking dials are quarantined on (a black-holed address can
        // never stall the event loop or a submit)
        let rec_shared = Arc::clone(&shared);
        let spawn_reconnector = || {
            std::thread::Builder::new()
                .name("tqdit-net-reconnect".into())
                .spawn(move || reconnector_loop(rec_shared))
                .context("spawning cluster reconnector thread")
        };
        if opts.reactor {
            let driver = ClusterDriver {
                shared: Arc::clone(&shared),
                tokens: HashMap::new(),
            };
            let (reactor, handle, _) =
                Reactor::spawn(driver, Vec::new(),
                               ReactorOpts::default())
                    .context("spawning cluster reactor")?;
            let _ = shared.reactor.set(handle.clone());
            for (i, stream, plane) in reader_specs {
                let ep = shared.lock().epoch[i];
                let tag = ClusterTag { shard: i, plane, epoch: ep };
                if !handle.register(stream, tag) {
                    bail!("cluster reactor stopped during connect");
                }
            }
            wait_registered(&shared);
            handle.timer(Instant::now() + opts.health.heartbeat,
                         HEARTBEAT_TIMER);
            return Ok(Cluster {
                shared,
                next_id: AtomicU64::new(0),
                monitor: None,
                reconnector: Some(spawn_reconnector()?),
                reactor: Some(reactor),
                t_start: Instant::now(),
            });
        }
        for (i, stream, plane) in reader_specs {
            let ep = shared.lock().epoch[i];
            spawn_reader(&shared, i, ep, stream, plane)?;
        }
        let mon_shared = Arc::clone(&shared);
        let monitor = std::thread::Builder::new()
            .name("tqdit-net-monitor".into())
            .spawn(move || monitor_loop(mon_shared))
            .context("spawning cluster monitor thread")?;
        Ok(Cluster {
            shared,
            next_id: AtomicU64::new(0),
            monitor: Some(monitor),
            reconnector: Some(spawn_reconnector()?),
            reactor: None,
            t_start: Instant::now(),
        })
    }

    /// Submit a request to the least-loaded placeable shard. Same
    /// contract as the local router's `submit`; the one new failure
    /// mode is [`ServeError::NodeLost`] when no shard is serving
    /// (reconnection may re-admit one later — clients can retry).
    /// Mints a fresh trace for the request (a no-op id when tracing
    /// is off).
    pub fn submit(&self, req: GenRequest)
                  -> std::result::Result<(u64, Receiver<GenResult>),
                                         ServeError> {
        self.submit_traced(req, trace::mint())
    }

    /// [`Self::submit`] under an externally minted trace context:
    /// `parent.trace` keys the request's spans and `parent.span`
    /// parents the request root. The frontend pre-mints a dispatch
    /// span id per hop and sends it with the submit when the shard's
    /// data plane negotiated [`WIRE_TRACE`] — the node's spans come
    /// home on the response and stitch under that hop; below the
    /// trace wire the node just sees an untraced submit and the
    /// timeline keeps its frontend half only.
    pub fn submit_traced(&self, req: GenRequest, parent: TraceCtx)
                         -> std::result::Result<(u64, Receiver<GenResult>),
                                                ServeError> {
        let ctx = if parent.is_active() {
            TraceCtx { trace: parent.trace, span: trace::next_id() }
        } else {
            TraceCtx::NONE
        };
        let shard;
        let epoch;
        let id;
        let rx;
        let msg;
        {
            let mut st = self.shared.lock();
            if !st.open {
                return Err(ServeError::ShuttingDown);
            }
            if st.health.serving_count() == 0 {
                return Err(ServeError::NodeLost {
                    cause: st
                        .first_cause
                        .clone()
                        .unwrap_or_else(|| "no live shard nodes".into()),
                });
            }
            if req.n > self.shared.opts.max_queue {
                return Err(ServeError::RequestTooLarge {
                    n: req.n,
                    cap: self.shared.opts.max_queue,
                });
            }
            let queued: usize = st.inflight.iter().sum();
            if queued + req.n > self.shared.opts.max_queue {
                return Err(ServeError::QueueFull {
                    queued,
                    cap: self.shared.opts.max_queue,
                });
            }
            id = self.next_id.fetch_add(1, Ordering::Relaxed);
            st.requests += 1;
            let (tx, rx_) = channel();
            rx = rx_;
            if req.n == 0 {
                // nothing to compute: complete immediately, no wire
                let _ = tx.send(Ok(GenResponse {
                    id,
                    images: Vec::new(),
                    latency_s: 0.0,
                }));
                return Ok((id, rx));
            }
            shard = match st.health.pick(&st.inflight) {
                Some(s) => s,
                // serving_count was checked above, but the health map
                // is shared state: fail the request typed, not the
                // process, if it emptied in between
                None => {
                    return Err(ServeError::NodeLost {
                        cause: "no serving shard available".into(),
                    });
                }
            };
            epoch = st.epoch[shard];
            let (dispatch_span, now_ns) = if ctx.is_active() {
                (trace::next_id(), trace::now_ns())
            } else {
                (0, 0)
            };
            // the trace rides the wire only once this shard's data
            // plane has acknowledged WIRE_TRACE — an older peer just
            // sees the untraced submit it has always understood
            let wire_trace = if ctx.is_active()
                && st.wire[shard] >= WIRE_TRACE
            {
                TraceCtx { trace: ctx.trace, span: dispatch_span }
            } else {
                TraceCtx::NONE
            };
            st.pending.insert(id, ClusterPending {
                class: req.class,
                n: req.n,
                tx,
                shard,
                t0: Instant::now(),
                trace: ctx,
                parent_span: parent.span,
                t0_ns: now_ns,
                dispatch_span,
                dispatch_t0_ns: now_ns,
            });
            st.inflight[shard] += req.n;
            msg = Msg::Submit {
                id,
                class: req.class,
                n: req.n,
                trace: wire_trace,
            };
        }
        // the wire write happens outside the state lock; on failure the
        // lost-node path re-queues (or typed-fails) this very request
        if let Err(cause) = send_data(&self.shared, shard, &msg) {
            shard_lost(&self.shared, shard, epoch, &cause);
        }
        Ok((id, rx))
    }

    /// Slots submitted but not yet answered (local estimate).
    pub fn queue_depth(&self) -> usize {
        self.shared.lock().inflight.iter().sum()
    }

    /// Sum of live worker counts the serving shards last reported.
    pub fn live_workers(&self) -> usize {
        self.shared.lock().health.live_workers_total()
    }

    /// Sum of ready worker counts the serving shards last reported.
    pub fn ready_workers(&self) -> usize {
        self.shared.lock().health.ready_workers_total()
    }

    /// Shards currently serving (Alive or Suspect; a dead shard
    /// re-enters this count once re-admitted).
    pub fn live_shards(&self) -> usize {
        self.shared.lock().health.serving_count()
    }

    /// Recovered shards re-admitted into placement so far — the cheap
    /// healing signal to poll (one lock, one load; `stats()` would
    /// aggregate every snapshot and sort the latency ring per call).
    pub fn nodes_readmitted(&self) -> u64 {
        self.shared.lock().nodes_readmitted
    }

    /// Aggregate of the latest shard snapshots + cluster-level
    /// overlay (see module docs). The monitor refreshes shard
    /// snapshots on the heartbeat cadence, so node-side counters are
    /// at most one interval stale; a shard that never answered (just
    /// connected, or dead before its first reply) contributes nothing
    /// yet.
    pub fn stats(&self) -> ServerStats {
        let st = self.shared.lock();
        aggregate(&st, self.t_start.elapsed().as_secs_f64())
    }

    /// Stop accepting, wait for in-flight requests to resolve (they
    /// complete on their shards, or fail typed when shards die), pull
    /// a final stats snapshot from every surviving shard, tear the
    /// connections down and return the aggregate.
    pub fn shutdown(mut self) -> ServerStats {
        {
            let mut st = self.shared.lock();
            st.open = false;
        }
        // 1. drain: in-flight work either completes on a live shard or
        // is failed typed by the lost-node path once the monitor (still
        // running) declares its shard dead — so this loop terminates.
        // A hard deadline bounds even a misbehaving-but-pinging shard.
        let patience = (self.shared.opts.health.timeout * 10)
            .max(Duration::from_secs(30));
        let deadline = Instant::now() + patience;
        {
            let mut st = self.shared.lock();
            while !st.pending.is_empty() {
                let now = Instant::now();
                if now >= deadline || st.health.serving_count() == 0 {
                    break;
                }
                let wait =
                    (deadline - now).min(Duration::from_millis(100));
                let (g, _) = self
                    .shared
                    .changed
                    .wait_timeout(st, wait)
                    .unwrap_or_else(|p| p.into_inner());
                st = g;
            }
            if !st.pending.is_empty() {
                warn_log!("cluster: shutdown with {} request(s) still \
                           unresolved; failing them typed",
                          st.pending.len());
                fail_all_pending(&mut st, || ServeError::NodeLost {
                    cause: "cluster shut down with the request still \
                            in flight"
                        .into(),
                });
            }
        }
        // 2. final stats sweep from the survivors
        let want = {
            let mut st = self.shared.lock();
            st.stats_want += 1;
            st.stats_want
        };
        let survivors: Vec<(usize, u64)> = {
            let st = self.shared.lock();
            st.health
                .serving_indices()
                .into_iter()
                .map(|i| (i, st.epoch[i]))
                .collect()
        };
        for (i, ep) in survivors {
            if let Err(c) = send_control(&self.shared, i,
                                         &Msg::StatsReq { seq: want }) {
                shard_lost(&self.shared, i, ep,
                           &format!("stats request write failed: {c}"));
            }
        }
        {
            let stats_deadline =
                Instant::now() + self.shared.opts.health.timeout;
            let mut st = self.shared.lock();
            loop {
                let missing = st
                    .health
                    .serving_indices()
                    .into_iter()
                    .any(|i| st.stats_seen[i] < want);
                let now = Instant::now();
                if !missing || now >= stats_deadline {
                    break;
                }
                let (g, _) = self
                    .shared
                    .changed
                    .wait_timeout(st, stats_deadline - now)
                    .unwrap_or_else(|p| p.into_inner());
                st = g;
            }
        }
        // 3. teardown: expected closes from here on
        self.teardown();
        let st = self.shared.lock();
        aggregate(&st, self.t_start.elapsed().as_secs_f64())
    }

    /// Close every connection and join the reader/monitor/reconnector
    /// threads (idempotent; shared between shutdown and drop).
    fn teardown(&mut self) {
        {
            let mut st = self.shared.lock();
            st.closing = true;
        }
        self.shared.changed.notify_all();
        // reactor mode: stopping the loop drops every connection;
        // `closing` is already set, so nothing reads that as a loss
        if let Some(h) = self.shared.reactor.get() {
            h.stop();
        }
        if let Some(r) = self.reactor.take() {
            r.join();
        }
        for conn in &self.shared.conns {
            conn.close();
        }
        let readers: Vec<JoinHandle<()>> = {
            let mut g = crate::util::lock(&self.shared.readers);
            g.drain(..).collect()
        };
        for h in readers {
            let _ = h.join();
        }
        if let Some(h) = self.monitor.take() {
            let _ = h.join();
        }
        if let Some(h) = self.reconnector.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Cluster {
    /// A cluster dropped without `shutdown` still tears its threads
    /// down; anything in flight is failed typed — with the same
    /// in-flight bookkeeping as the shutdown path, so the stats a
    /// racing `stats()` reader sees stay conserved — never stranded.
    fn drop(&mut self) {
        {
            let mut st = self.shared.lock();
            st.open = false;
            fail_all_pending(&mut st, || ServeError::ShuttingDown);
        }
        self.teardown();
    }
}

impl Dispatch for Cluster {
    fn submit(&self, req: GenRequest)
              -> std::result::Result<(u64, Receiver<GenResult>),
                                     ServeError> {
        Cluster::submit(self, req)
    }
    fn submit_traced(&self, req: GenRequest, parent: TraceCtx)
                     -> std::result::Result<(u64, Receiver<GenResult>),
                                            ServeError> {
        Cluster::submit_traced(self, req, parent)
    }
    fn queue_depth(&self) -> usize {
        Cluster::queue_depth(self)
    }
    fn live_workers(&self) -> usize {
        Cluster::live_workers(self)
    }
    fn ready_workers(&self) -> usize {
        Cluster::ready_workers(self)
    }
    fn stats(&self) -> ServerStats {
        Cluster::stats(self)
    }
    fn shutdown(self: Box<Self>) -> ServerStats {
        Cluster::shutdown(*self)
    }
}

/// Fail every pending request with `err()`, decrementing the
/// in-flight book exactly like the delivery path — the one shared
/// cleanup for shutdown-stranded and dropped clusters (stats
/// conservation must not depend on *how* the cluster went away). A
/// request that vanished mid-iteration is a logged degradation, not a
/// panic, matching the delivery path.
fn fail_all_pending(st: &mut ClusterState,
                    err: impl Fn() -> ServeError) {
    let stranded: Vec<u64> = st.pending.keys().copied().collect();
    for id in stranded {
        let Some(p) = st.pending.remove(&id) else {
            debug_log!("cluster: request {id} already resolved while \
                        failing pending requests");
            continue;
        };
        st.inflight[p.shard] = st.inflight[p.shard].saturating_sub(p.n);
        st.failed_requests += 1;
        let _ = p.tx.send(Err(err()));
    }
}

/// Aggregate shard snapshots + cluster overlay (state lock held by the
/// caller).
fn aggregate(st: &ClusterState, wall_s: f64) -> ServerStats {
    let mut agg = ServerStats::default();
    for s in st.last_stats.iter().flatten() {
        agg.absorb(s);
    }
    // what only the frontend can see: the client-facing request
    // counts, re-queue/loss/re-admission accounting, and true
    // end-to-end latency
    agg.requests = st.requests;
    agg.failed_requests = st.failed_requests;
    agg.requeued = st.requeued;
    agg.nodes_lost = st.nodes_lost;
    agg.nodes_readmitted = st.nodes_readmitted;
    agg.wall_s = wall_s;
    // the frontend's histogram *replaces* the absorbed node-side one:
    // the nodes time queue+compute, the frontend times the client's
    // whole round trip, and the aggregate reports the latter
    agg.latency = st.latency.clone();
    agg.latency_p50_s = agg.latency.quantile(0.50);
    agg.latency_p95_s = agg.latency.quantile(0.95);
    agg
}

/// Write one message on a shard's data connection (its writer locks
/// only; never the state lock) via the layer-wide
/// [`send_message`](crate::serve::net::send_message) two-lock
/// discipline — oversized messages go as chunk runs with the frame
/// lock released between chunks. `Err` carries the cause for the
/// lost-node path.
// tq-lint: allow(transitive-blocking): mode dispatch — reactor-mode
// callers take the non-blocking reactor_send path, and threaded-mode
// callers are dedicated reader/monitor threads that are allowed to
// block on the socket
fn send_data(shared: &ClusterShared, shard: usize, msg: &Msg)
             -> std::result::Result<(), String> {
    if shared.opts.reactor {
        return reactor_send(shared, shard, msg, Role::Data);
    }
    let conn = &shared.conns[shard];
    crate::serve::net::send_message(&conn.data, &conn.bulk,
                                    &msg.encode())
        .map_err(|e| e.to_string())
}

/// Write one (small) message on a shard's control connection, falling
/// back to the data connection when the control plane is disabled.
fn send_control(shared: &ClusterShared, shard: usize, msg: &Msg)
                -> std::result::Result<(), String> {
    if !shared.opts.control_plane {
        return send_data(shared, shard, msg);
    }
    if shared.opts.reactor {
        return reactor_send(shared, shard, msg, Role::Control);
    }
    let mut g = crate::util::lock(&shared.conns[shard].ctrl);
    let Some(stream) = g.as_mut() else {
        return Err("control connection already closed".into());
    };
    // tq-lint: allow(lock-across-blocking): control frames are tiny
    // (one header + a short body) and the socket has a write timeout;
    // the ctrl mutex only serializes writers on this one stream
    write_frame(stream, &msg.encode()).map_err(|e| e.to_string())
}

/// Reactor-mode send: look up the shard's live token for `plane` and
/// route the encoded message through the reactor handle — bulk lane
/// for data traffic, ctrl-priority for the control plane. The gap
/// between a dial and its `on_open` surfaces as a typed error, which
/// callers treat like any other dead-connection write.
fn reactor_send(shared: &ClusterShared, shard: usize, msg: &Msg,
                plane: Role) -> std::result::Result<(), String> {
    let Some(handle) = shared.reactor.get() else {
        return Err("cluster reactor not started".into());
    };
    let token = {
        let st = shared.lock();
        match plane {
            Role::Data => st.data_token[shard],
            Role::Control => st.ctrl_token[shard],
        }
    };
    let Some(token) = token else {
        return Err(format!("{} connection not open", plane.name()));
    };
    let ok = match plane {
        Role::Data => handle.send(token, msg.encode()),
        Role::Control => handle.send_ctrl(token, msg.encode()),
    };
    if ok {
        Ok(())
    } else {
        Err("cluster reactor stopped".into())
    }
}

/// Deliver a terminal outcome for request `id` (from whichever shard
/// answered first — a request re-queued off a slow-but-alive shard may
/// legitimately resolve twice; the second is logged and dropped).
/// `spans` are the node's spans for the request (empty when untraced
/// or below the trace wire) — re-based and ingested here, then the
/// frontend's own dispatch-hop and request-root spans close over
/// them, so a clustered request reads as one stitched timeline.
fn complete(shared: &ClusterShared, id: u64,
            outcome: std::result::Result<Vec<f32>, ServeError>,
            spans: Vec<SpanRec>) {
    let mut st = shared.lock();
    let Some(p) = st.pending.remove(&id) else {
        debug_log!("cluster: late/duplicate answer for request {id} \
                    dropped");
        return;
    };
    st.inflight[p.shard] = st.inflight[p.shard].saturating_sub(p.n);
    let latency_s = p.t0.elapsed().as_secs_f64();
    if p.trace.is_active() && trace::tracing_on() {
        let end_ns = trace::now_ns();
        ingest_remote_spans(&p, &spans, end_ns);
        // both ids were pre-minted (the node parents under the
        // dispatch span; stage spans under the root), so the spans
        // are recorded verbatim rather than via `record_span`
        trace::record(SpanRec {
            trace: p.trace.trace,
            span: p.dispatch_span,
            parent: p.trace.span,
            kind: SpanKind::Dispatch,
            start_ns: p.dispatch_t0_ns,
            dur_ns: end_ns.saturating_sub(p.dispatch_t0_ns),
            a: p.shard as u64,
            b: spans.len() as u64,
        });
        trace::record(SpanRec {
            trace: p.trace.trace,
            span: p.trace.span,
            parent: p.parent_span,
            kind: SpanKind::Request,
            start_ns: p.t0_ns,
            dur_ns: end_ns.saturating_sub(p.t0_ns),
            a: 0,
            b: p.n as u64,
        });
    }
    match outcome {
        Ok(images) => {
            st.latency.record(latency_s);
            let _ = p.tx.send(Ok(GenResponse { id, images, latency_s }));
        }
        Err(err) => {
            st.failed_requests += 1;
            let _ = p.tx.send(Err(err));
        }
    }
    let drained = st.pending.is_empty();
    drop(st);
    if drained {
        shared.changed.notify_all();
    }
}

/// Re-base a node's spans — timed on the *node's* monotonic clock —
/// into this process's timeline before ingesting them: the node's
/// whole reported interval is centered inside the frontend's dispatch
/// window, splitting the unobservable wire time evenly between the
/// two directions. Spans from other traces (a confused peer) are
/// dropped rather than ingested under the wrong timeline.
fn ingest_remote_spans(p: &ClusterPending, spans: &[SpanRec],
                       end_ns: u64) {
    let anchor = spans
        .iter()
        .filter(|r| r.trace == p.trace.trace)
        .min_by_key(|r| r.start_ns);
    let Some(anchor) = anchor else { return };
    let node_span = spans
        .iter()
        .filter(|r| r.trace == p.trace.trace)
        .map(|r| r.start_ns.saturating_sub(anchor.start_ns) + r.dur_ns)
        .max()
        .unwrap_or(0);
    let hop = end_ns.saturating_sub(p.dispatch_t0_ns);
    let base = p.dispatch_t0_ns + hop.saturating_sub(node_span) / 2;
    for r in spans {
        if r.trace != p.trace.trace {
            continue;
        }
        let mut rec = *r;
        rec.start_ns =
            base + rec.start_ns.saturating_sub(anchor.start_ns);
        trace::record(rec);
    }
}

/// Declare a shard dead and re-home its in-flight requests: each is
/// resubmitted to the least-loaded survivor, or failed with a typed
/// [`ServeError::NodeLost`] when none remains. `epoch` is the
/// connection generation the caller observed failing — a report about
/// a connection the reconnector already replaced is ignored. The
/// cleanup runs exactly once per death episode (`Health::mark_dead`
/// reports the previous state); resubmit write failures cascade
/// iteratively, never recursively. A probation shard dying is just a
/// failed revival: back to reconnecting, nothing to re-home, not
/// another loss.
fn shard_lost(shared: &ClusterShared, shard: usize, epoch: u64,
              cause: &str) {
    let mut work: Vec<(usize, u64, String)> =
        vec![(shard, epoch, cause.to_string())];
    while let Some((i, ep, cause)) = work.pop() {
        let mut resubmits: Vec<(usize, u64, Msg)> = Vec::new();
        {
            let mut st = shared.lock();
            if st.epoch[i] != ep {
                continue; // stale: a newer connection owns this shard
            }
            let prev = st.health.mark_dead(i);
            if prev == ShardState::Dead {
                continue; // already handled by a racing path
            }
            // pace the revival: first re-dial one reconnect interval
            // after the death, then every interval
            st.last_reconnect[i] = Some(Instant::now());
            if st.closing {
                continue; // deliberate teardown, not a loss
            }
            if prev == ShardState::Probation {
                debug_log!("cluster: shard {} fell back to dead during \
                            probation: {}",
                           shared.addrs[i], cause);
                drop(st);
                close_if_epoch(shared, i, ep);
                shared.changed.notify_all();
                continue;
            }
            st.nodes_lost += 1;
            // drop the dead shard's snapshot: its in-flight slots are
            // about to be re-enqueued (and so re-counted) on the
            // survivors, and a stale snapshot would double-count them
            // and report phantom `pending` forever
            st.last_stats[i] = None;
            let full_cause =
                format!("shard {}: {}", shared.addrs[i], cause);
            warn_log!("cluster: node lost — {full_cause}; re-queuing \
                       its in-flight requests");
            if st.first_cause.is_none() {
                st.first_cause = Some(full_cause.clone());
            }
            st.inflight[i] = 0;
            let moved: Vec<u64> = st
                .pending
                .iter()
                .filter(|(_, p)| p.shard == i)
                .map(|(&id, _)| id)
                .collect();
            for id in moved {
                match st.health.pick(&st.inflight) {
                    Some(j) => {
                        let ep_j = st.epoch[j];
                        let wire_j = st.wire[j];
                        let Some(p) = st.pending.get_mut(&id) else {
                            debug_log!("cluster: request {id} resolved \
                                        while being re-homed");
                            continue;
                        };
                        p.shard = j;
                        // a re-homed request starts a fresh dispatch
                        // hop: new span id, new send time, same gating
                        // on the survivor's acknowledged wire level
                        let wire_trace = if p.trace.is_active() {
                            p.dispatch_span = trace::next_id();
                            p.dispatch_t0_ns = trace::now_ns();
                            if wire_j >= WIRE_TRACE {
                                TraceCtx {
                                    trace: p.trace.trace,
                                    span: p.dispatch_span,
                                }
                            } else {
                                TraceCtx::NONE
                            }
                        } else {
                            TraceCtx::NONE
                        };
                        let (class, n) = (p.class, p.n);
                        st.inflight[j] += n;
                        st.requeued += 1;
                        resubmits.push((j, ep_j, Msg::Submit {
                            id,
                            class,
                            n,
                            trace: wire_trace,
                        }));
                    }
                    None => {
                        let Some(p) = st.pending.remove(&id) else {
                            debug_log!("cluster: request {id} resolved \
                                        while being re-homed");
                            continue;
                        };
                        st.failed_requests += 1;
                        let _ = p.tx.send(Err(ServeError::NodeLost {
                            cause: format!(
                                "{full_cause}; no surviving shard to \
                                 take the request"
                            ),
                        }));
                    }
                }
            }
        }
        // close both halves outside the state lock; this also unblocks
        // the shard's reader threads, whose own loss reports then land
        // on the already-dead state and no-op
        close_if_epoch(shared, i, ep);
        shared.changed.notify_all();
        for (j, ep_j, msg) in resubmits {
            if let Err(c) = send_data(shared, j, &msg) {
                work.push((j, ep_j, c));
            }
        }
    }
}

/// Close a shard's connections only while `ep` is still its live
/// epoch: the lost-node path closes *after* releasing the state lock,
/// and with a tiny `--reconnect-ms` the reconnector may have already
/// installed a replacement — a stale deferred close must not kill it.
/// (The remaining instruction-wide window self-heals: a clipped
/// probation connection just falls back to Dead and is re-dialed.)
fn close_if_epoch(shared: &ClusterShared, i: usize, ep: u64) {
    if shared.opts.reactor {
        // handle-requested closes fire no `on_close`, so taking the
        // tokens here is the whole cleanup
        let (data, ctrl) = {
            let mut st = shared.lock();
            if st.epoch[i] != ep {
                return;
            }
            (st.data_token[i].take(), st.ctrl_token[i].take())
        };
        if let Some(h) = shared.reactor.get() {
            for t in [data, ctrl].into_iter().flatten() {
                h.close(t);
            }
        }
        return;
    }
    let still_ours = shared.lock().epoch[i] == ep;
    if still_ours {
        shared.conns[i].close();
    }
}

/// Spawn one reader thread for a shard connection. Data-plane readers
/// are wrapped in a [`CountingReader`] feeding the stall check.
fn spawn_reader(shared: &Arc<ClusterShared>, shard: usize, epoch: u64,
                stream: TcpStream, plane: Role) -> Result<()> {
    let rd_shared = Arc::clone(shared);
    let name = format!("tqdit-net-read-{shard}-{}", plane.name());
    let counter = Arc::clone(&shared.data_bytes[shard]);
    let h = std::thread::Builder::new()
        .name(name)
        .spawn(move || match plane {
            Role::Data => reader_loop(rd_shared, shard, epoch,
                                      CountingReader {
                                          inner: stream,
                                          bytes: counter,
                                      },
                                      plane),
            Role::Control => {
                reader_loop(rd_shared, shard, epoch, stream, plane)
            }
        })
        .context("spawning cluster reader thread")?;
    let mut g = crate::util::lock(&shared.readers);
    // reap finished readers so a long-lived frontend does not grow a
    // handle per reconnect it ever performed
    g.retain(|h| !h.is_finished());
    g.push(h);
    Ok(())
}

/// Per-connection reader: pumps frames into deliveries, heartbeat
/// records and stats snapshots until the connection dies (loss or
/// teardown). Data and control connections run the same loop — the
/// message types themselves say what to do.
fn reader_loop<R: Read>(shared: Arc<ClusterShared>, shard: usize,
                        epoch: u64, mut stream: R, plane: Role) {
    let mut messages = MessageReader::new();
    loop {
        let payload = match messages.read(&mut stream) {
            Ok(p) => p,
            Err(WireError::Closed) => {
                shard_lost(&shared, shard, epoch, "connection closed");
                return;
            }
            Err(e) => {
                shard_lost(&shared, shard, epoch, &e.to_string());
                return;
            }
        };
        // a bad message in a good frame degrades that message only
        let msg = match Msg::decode(&payload) {
            Ok(m) => m,
            Err(e) => {
                warn_log!("cluster: shard {}: skipping bad message: \
                           {e:#}",
                          shared.addrs[shard]);
                continue;
            }
        };
        match msg {
            Msg::Response { id, images, spans, .. } => {
                complete(&shared, id, Ok(images), spans);
            }
            Msg::ErrorResp { id, err } => {
                complete(&shared, id, Err(err), Vec::new());
            }
            Msg::Pong { queue_depth, live_workers, ready_workers, .. } => {
                // with the control plane isolated, only control-plane
                // pongs count as liveness evidence — the data-plane
                // pong exists to move bytes for the stall probe, and
                // feeding it to `Health::pong` would run the
                // probation streak and the ramp decay at double rate
                if plane == Role::Data && shared.opts.control_plane {
                    continue;
                }
                let mut st = shared.lock();
                if st.epoch[shard] != epoch {
                    continue; // stale connection's pong
                }
                let readmitted = st.health.pong(
                    shard, queue_depth, live_workers, ready_workers,
                    Instant::now());
                if readmitted {
                    st.nodes_readmitted += 1;
                    warn_log!("cluster: shard {} re-admitted after {} \
                               consecutive pong(s); ramping placement \
                               back up",
                              shared.addrs[shard],
                              shared.opts.health.readmit_pongs);
                    drop(st);
                    // placement capacity changed
                    shared.changed.notify_all();
                }
            }
            Msg::Stats { seq, stats } => {
                let mut st = shared.lock();
                // a snapshot racing the shard's death must not
                // resurrect the cleared entry (its slots re-count on
                // the survivors); stale-epoch snapshots equally so
                if st.epoch[shard] == epoch
                    && st.health.shard(shard).serving()
                {
                    st.last_stats[shard] = Some(stats);
                    st.stats_seen[shard] =
                        st.stats_seen[shard].max(seq);
                }
                drop(st);
                shared.changed.notify_all();
            }
            Msg::HelloAck { wire } => {
                debug_log!("cluster: shard {}: wire level {wire} \
                            acknowledged", shared.addrs[shard]);
                // trace ids go on the wire only once the data plane
                // has acknowledged a level that understands them
                if plane == Role::Data {
                    let mut st = shared.lock();
                    if st.epoch[shard] == epoch {
                        st.wire[shard] = wire;
                    }
                }
            }
            Msg::StatsDelta { .. } => {
                // delta pushes are the reactor frontend's diet; the
                // threaded reader polls full snapshots instead
            }
            other => {
                warn_log!("cluster: shard {}: skipping unexpected {} \
                           message",
                          shared.addrs[shard], other.kind());
            }
        }
    }
}

/// Heartbeat monitor: pings every connected shard (serving *and*
/// probation — pongs are a probation shard's path back in) each
/// interval and declares the ones past the timeout dead. The condvar
/// wait lets teardown interrupt a sleeping monitor immediately;
/// spurious wakes (delivery notifications share the condvar) are
/// cheap because pings are rate-limited to the heartbeat cadence.
fn monitor_loop(shared: Arc<ClusterShared>) {
    let heartbeat = shared.opts.health.heartbeat;
    let mut last_ping: Option<Instant> = None;
    loop {
        {
            let st = shared.lock();
            if st.closing {
                return;
            }
            let remaining = match last_ping {
                None => Duration::ZERO,
                Some(at) => heartbeat
                    .saturating_sub(at.elapsed()),
            };
            if !remaining.is_zero() {
                let (g, _) = shared
                    .changed
                    .wait_timeout(st, remaining)
                    .unwrap_or_else(|p| p.into_inner());
                if g.closing {
                    return;
                }
            }
        }
        if let Some(at) = last_ping {
            if at.elapsed() < heartbeat {
                continue; // woken by a notification, not the cadence
            }
        }
        last_ping = Some(Instant::now());
        let (seq, stats_seq, targets) = {
            let mut st = shared.lock();
            st.ping_seq += 1;
            // stats requests ride the heartbeat cadence so
            // `Cluster::stats()` is never more than one interval
            // stale; the shutdown sweep bumps the same counter, so
            // its wait still demands a strictly fresher snapshot
            st.stats_want += 1;
            let targets: Vec<(usize, u64)> = st
                .health
                .ping_targets()
                .into_iter()
                .map(|i| (i, st.epoch[i]))
                .collect();
            (st.ping_seq, st.stats_want, targets)
        };
        for &(i, ep) in &targets {
            if let Err(c) =
                send_control(&shared, i, &Msg::Ping { seq })
            {
                shard_lost(&shared, i, ep,
                           &format!("heartbeat write failed: {c}"));
                continue;
            }
            let _ = send_control(&shared, i,
                                 &Msg::StatsReq { seq: stats_seq });
        }
        // data-plane probe: with the control plane isolated, control
        // pongs no longer prove the data path can move bytes — ping
        // it too (the pong interleaves between response chunks) and
        // watch byte-level read progress, so a half-open data
        // connection fails in ~data_stall_deadline instead of the
        // kernel's minutes-long retransmission give-up
        if shared.opts.control_plane {
            for &(i, ep) in &targets {
                if let Err(c) = send_data(&shared, i, &Msg::Ping { seq })
                {
                    shard_lost(&shared, i, ep,
                               &format!("data-plane heartbeat write \
                                         failed: {c}"));
                }
            }
            let stall =
                data_stall_deadline(shared.opts.health.timeout);
            let stalled: Vec<(usize, u64)> = {
                let mut st = shared.lock();
                let now = Instant::now();
                let mut out = Vec::new();
                for i in st.health.serving_indices() {
                    let bytes =
                        shared.data_bytes[i].load(Ordering::Relaxed);
                    let (last_bytes, since) = st.data_progress[i];
                    if bytes != last_bytes || st.inflight[i] == 0 {
                        // progress, or nothing owed: reset the clock
                        st.data_progress[i] = (bytes, now);
                    } else if now.saturating_duration_since(since)
                        > stall
                    {
                        out.push((i, st.epoch[i]));
                    }
                }
                out
            };
            for (i, ep) in stalled {
                shard_lost(&shared, i, ep,
                           &format!("data plane stalled: requests in \
                                     flight but zero bytes read for \
                                     > {stall:?}"));
            }
        }
        let expired: Vec<(usize, u64)> = {
            let mut st = shared.lock();
            let now = Instant::now();
            st.health.tick(now);
            st.health
                .expired(now)
                .into_iter()
                .map(|i| (i, st.epoch[i]))
                .collect()
        };
        for (i, ep) in expired {
            let timeout = shared.opts.health.timeout;
            shard_lost(&shared, i, ep,
                       &format!("heartbeat timeout (> {timeout:?})"));
        }
    }
}

/// Reconnector: re-dials dead shards every reconnect interval. A
/// revived shard is installed under a fresh epoch and enters
/// Probation — the monitor's pings (answered on the new control
/// connection) walk it back to Alive. Blocking dials happen on this
/// thread only, so a black-holed address can never stall the
/// heartbeat monitor or a submit.
fn reconnector_loop(shared: Arc<ClusterShared>) {
    loop {
        let due: Vec<usize> = {
            let mut st = shared.lock();
            if st.closing {
                return;
            }
            let now = Instant::now();
            let interval = shared.opts.reconnect;
            let due: Vec<usize> = st
                .health
                .dead_indices()
                .into_iter()
                .filter(|&i| match st.last_reconnect[i] {
                    Some(at) => {
                        now.saturating_duration_since(at) >= interval
                    }
                    None => true,
                })
                .collect();
            for &i in &due {
                st.last_reconnect[i] = Some(now);
            }
            due
        };
        for i in due {
            try_reconnect(&shared, i);
        }
        let st = shared.lock();
        if st.closing {
            return;
        }
        let (g, _) = shared
            .changed
            .wait_timeout(st, shared.opts.reconnect)
            .unwrap_or_else(|p| p.into_inner());
        if g.closing {
            return;
        }
    }
}

/// One reconnect attempt for a dead shard: dial data (+ control),
/// install the write halves while the shard is still Dead (nothing
/// sends to a dead shard, so the swap is race-free), then flip it to
/// Probation under a fresh epoch and spawn its readers.
fn try_reconnect(shared: &Arc<ClusterShared>, i: usize) {
    let addr = &shared.addrs[i];
    let (data, ctrl) = match dial_shard(addr, &shared.opts) {
        Ok(pair) => pair,
        Err(e) => {
            debug_log!("cluster: shard {addr} still down: {e}");
            return;
        }
    };
    if shared.opts.reactor {
        // flip to Probation under the fresh epoch *before* handing the
        // streams over: `on_open` records tokens only while the tag's
        // epoch is current, so a register landing after yet another
        // death is quietly dropped
        let epoch = {
            let mut st = shared.lock();
            if st.closing || st.health.state(i) != ShardState::Dead {
                return;
            }
            st.epoch[i] += 1;
            st.wire[i] = 0; // renegotiated by the fresh hello/ack
            st.health.begin_probation(i, Instant::now());
            st.epoch[i]
        };
        warn_log!("cluster: shard {addr} reconnected; probing before \
                   re-admission");
        let Some(handle) = shared.reactor.get() else { return };
        let mut ok = handle.register(
            data, ClusterTag { shard: i, plane: Role::Data, epoch });
        if let Some(c) = ctrl {
            ok &= handle.register(
                c, ClusterTag { shard: i, plane: Role::Control, epoch });
        }
        if !ok {
            // failed revival, same as a reader-spawn failure
            shard_lost(shared, i, epoch, "cluster reactor stopped");
        }
        return;
    }
    let (data_rd, ctrl_rd) = match (
        data.try_clone(),
        ctrl.as_ref().map(TcpStream::try_clone).transpose(),
    ) {
        (Ok(d), Ok(c)) => (d, c),
        (Err(e), _) | (_, Err(e)) => {
            debug_log!("cluster: shard {addr}: clone failed: {e}");
            return;
        }
    };
    {
        let mut g = crate::util::lock(&shared.conns[i].data);
        *g = Some(data);
    }
    {
        let mut g = crate::util::lock(&shared.conns[i].ctrl);
        *g = ctrl;
    }
    let epoch = {
        let mut st = shared.lock();
        if st.closing || st.health.state(i) != ShardState::Dead {
            drop(st);
            shared.conns[i].close();
            return;
        }
        st.epoch[i] += 1;
        st.wire[i] = 0; // renegotiated by the fresh hello/ack
        st.health.begin_probation(i, Instant::now());
        st.epoch[i]
    };
    warn_log!("cluster: shard {addr} reconnected; probing before \
               re-admission");
    if spawn_reader(shared, i, epoch, data_rd, Role::Data).is_err()
        || match ctrl_rd {
            Some(c) => {
                spawn_reader(shared, i, epoch, c, Role::Control).is_err()
            }
            None => false,
        }
    {
        // thread spawn failed: treat as a failed revival
        shard_lost(shared, i, epoch, "spawning reader threads failed");
    }
}

// ---------------------------------------------------------------------
// Reactor mode

/// Timer key of the heartbeat sweep — the cluster driver's only timer.
const HEARTBEAT_TIMER: u64 = 0;

/// Connection identity carried through `Handle::register`: which
/// shard, which plane, and the epoch the dial was made under — the
/// reactor-mode twin of the `(shard, epoch, plane)` triple each
/// threaded reader thread closes over. Stale epochs make a late
/// `on_open` or loss report inert, exactly like the threaded path.
#[derive(Clone, Copy, Debug)]
struct ClusterTag {
    shard: usize,
    plane: Role,
    epoch: u64,
}

/// Fold one [`Msg::StatsDelta`] push into a shard's accumulated
/// snapshot: counters add, gauges and the rung/worker breakdowns stay
/// absolute — the exact inverse of the node's `stats_delta` (the two
/// must agree on which fields are counters). The first push on a
/// connection carries full cumulative values, so an empty accumulator
/// starts from the push itself; the conservation identity `enqueued ==
/// dispatched + purged + pending` holds on every folded value because
/// each one equals the node's cumulative counters at push time.
fn stats_fold(acc: &ServerStats, d: &ServerStats) -> ServerStats {
    let mut next = d.clone();
    next.requests = acc.requests + d.requests;
    next.images = acc.images + d.images;
    next.batches = acc.batches + d.batches;
    next.padded_slots = acc.padded_slots + d.padded_slots;
    next.failed_requests = acc.failed_requests + d.failed_requests;
    next.dropped_responses =
        acc.dropped_responses + d.dropped_responses;
    next.calib_cache_hits = acc.calib_cache_hits + d.calib_cache_hits;
    next.calib_cache_misses =
        acc.calib_cache_misses + d.calib_cache_misses;
    next.enqueued = acc.enqueued + d.enqueued;
    next.dispatched = acc.dispatched + d.dispatched;
    next.purged = acc.purged + d.purged;
    next.requeued = acc.requeued + d.requeued;
    next.nodes_lost = acc.nodes_lost + d.nodes_lost;
    next.nodes_readmitted = acc.nodes_readmitted + d.nodes_readmitted;
    next.reuse_hits = acc.reuse_hits + d.reuse_hits;
    next.steps_skipped = acc.steps_skipped + d.steps_skipped;
    next.uploads_saved = acc.uploads_saved + d.uploads_saved;
    // the latency histogram travels as a per-bucket increment
    // (`LatencyHist::delta_since` on the node), so folding is a
    // merge; the quantile gauges re-derive from the folded buckets
    next.latency = acc.latency.clone();
    next.latency.merge(&d.latency);
    if next.latency.count() > 0 {
        next.latency_p50_s = next.latency.quantile(0.50);
        next.latency_p95_s = next.latency.quantile(0.95);
    }
    next
}

/// Block (bounded) until the reactor's `on_open` has recorded tokens
/// for every shard dialed at connect — placement and heartbeats route
/// by token, so the first submit must not race the registration
/// handoff into a spurious node loss. A shard whose registration never
/// lands (reactor died) is declared lost the normal way.
fn wait_registered(shared: &Arc<ClusterShared>) {
    let deadline = Instant::now() + Duration::from_secs(5);
    let missing = |st: &ClusterState, i: usize| {
        st.data_token[i].is_none()
            || (shared.opts.control_plane && st.ctrl_token[i].is_none())
    };
    let mut st = shared.lock();
    loop {
        let any = st
            .health
            .serving_indices()
            .into_iter()
            .any(|i| missing(&st, i));
        let now = Instant::now();
        if !any || now >= deadline {
            break;
        }
        let (g, _) = shared
            .changed
            .wait_timeout(st, deadline - now)
            .unwrap_or_else(|p| p.into_inner());
        st = g;
    }
    let stragglers: Vec<(usize, u64)> = st
        .health
        .serving_indices()
        .into_iter()
        .filter(|&i| missing(&st, i))
        .map(|i| (i, st.epoch[i]))
        .collect();
    drop(st);
    for (i, ep) in stragglers {
        shard_lost(shared, i, ep, "reactor registration timed out");
    }
}

/// The cluster frontend's [`Driver`]: `reader_loop` and `monitor_loop`
/// re-expressed as callbacks on one reactor thread. Callbacks only
/// decode, update shared state and enqueue writes — compute lives on
/// the nodes, blocking dials on the reconnector thread.
struct ClusterDriver {
    shared: Arc<ClusterShared>,
    /// Live token → identity (reactor-thread local). Entries for
    /// connections closed through the handle (which fires no
    /// `on_close`) are pruned by the heartbeat sweep once their epoch
    /// is outrun.
    tokens: HashMap<Token, ClusterTag>,
}

impl Driver for ClusterDriver {
    type Tag = ClusterTag;

    fn accept_tag(&mut self, _listener: Token, _peer: SocketAddr)
                  -> ClusterTag {
        // the cluster reactor runs zero listeners; nothing accepts
        ClusterTag { shard: usize::MAX, plane: Role::Data, epoch: 0 }
    }

    fn on_open(&mut self, ctl: &mut Ctl<'_>, token: Token,
               tag: ClusterTag) {
        let stale = {
            let mut st = self.shared.lock();
            if st.closing || tag.shard >= st.epoch.len()
                || st.epoch[tag.shard] != tag.epoch
            {
                true
            } else {
                match tag.plane {
                    Role::Data => {
                        st.data_token[tag.shard] = Some(token)
                    }
                    Role::Control => {
                        st.ctrl_token[tag.shard] = Some(token)
                    }
                }
                false
            }
        };
        if stale {
            // a dial the epoch outran (the shard died again, or
            // teardown started): drop it without a loss report
            ctl.close(token);
            return;
        }
        self.tokens.insert(token, tag);
        self.shared.changed.notify_all();
    }

    fn on_message(&mut self, _ctl: &mut Ctl<'_>, token: Token,
                  payload: Vec<u8>) {
        let Some(&tag) = self.tokens.get(&token) else { return };
        let shared = Arc::clone(&self.shared);
        let shard = tag.shard;
        // a bad message in a good frame degrades that message only
        let msg = match Msg::decode(&payload) {
            Ok(m) => m,
            Err(e) => {
                warn_log!("cluster: shard {}: skipping bad message: \
                           {e:#}",
                          shared.addrs[shard]);
                return;
            }
        };
        match msg {
            Msg::Response { id, images, spans, .. } => {
                complete(&shared, id, Ok(images), spans);
            }
            Msg::ErrorResp { id, err } => {
                complete(&shared, id, Err(err), Vec::new());
            }
            Msg::Pong { queue_depth, live_workers, ready_workers, .. } => {
                // same liveness discipline as the threaded reader:
                // with the control plane isolated, only control pongs
                // count as evidence — the data-plane pong exists to
                // move bytes for the stall probe
                if tag.plane == Role::Data && shared.opts.control_plane {
                    return;
                }
                let mut st = shared.lock();
                if st.epoch[shard] != tag.epoch {
                    return; // stale connection's pong
                }
                let readmitted = st.health.pong(
                    shard, queue_depth, live_workers, ready_workers,
                    Instant::now());
                if readmitted {
                    st.nodes_readmitted += 1;
                    warn_log!("cluster: shard {} re-admitted after {} \
                               consecutive pong(s); ramping placement \
                               back up",
                              shared.addrs[shard],
                              shared.opts.health.readmit_pongs);
                    drop(st);
                    shared.changed.notify_all();
                }
            }
            Msg::Stats { seq, stats } => {
                let mut st = shared.lock();
                // a snapshot racing the shard's death must not
                // resurrect the cleared entry; stale epochs equally so
                if st.epoch[shard] == tag.epoch
                    && st.health.shard(shard).serving()
                {
                    st.last_stats[shard] = Some(stats);
                    st.stats_seen[shard] =
                        st.stats_seen[shard].max(seq);
                }
                drop(st);
                shared.changed.notify_all();
            }
            Msg::StatsDelta { stats } => {
                let mut st = shared.lock();
                if st.epoch[shard] == tag.epoch
                    && st.health.shard(shard).serving()
                {
                    let folded = match st.last_stats[shard].take() {
                        Some(acc) => stats_fold(&acc, &stats),
                        None => stats,
                    };
                    st.last_stats[shard] = Some(folded);
                    // the delta stream is live: the heartbeat stops
                    // polling full snapshots for this epoch
                    st.delta_epoch[shard] = tag.epoch;
                }
                drop(st);
                shared.changed.notify_all();
            }
            Msg::HelloAck { wire } => {
                debug_log!("cluster: shard {}: wire level {wire} \
                            acknowledged", shared.addrs[shard]);
                // same gating as the threaded reader: only the data
                // plane's acknowledged level admits trace ids
                if tag.plane == Role::Data {
                    let mut st = shared.lock();
                    if st.epoch[shard] == tag.epoch {
                        st.wire[shard] = wire;
                    }
                }
            }
            Msg::Reject { err } => {
                // the node refused this connection outright (e.g. it
                // could not staff a handler for it)
                shard_lost(&shared, shard, tag.epoch,
                           &format!("node rejected the connection: \
                                     {err}"));
            }
            other => {
                warn_log!("cluster: shard {}: skipping unexpected {} \
                           message",
                          shared.addrs[shard], other.kind());
            }
        }
    }

    fn on_close(&mut self, _ctl: &mut Ctl<'_>, token: Token,
                cause: WireError) {
        let Some(tag) = self.tokens.remove(&token) else { return };
        {
            let mut st = self.shared.lock();
            let slot = match tag.plane {
                Role::Data => &mut st.data_token[tag.shard],
                Role::Control => &mut st.ctrl_token[tag.shard],
            };
            if *slot == Some(token) {
                *slot = None;
            }
        }
        let cause = match cause {
            WireError::Closed => "connection closed".to_string(),
            e => e.to_string(),
        };
        // `shard_lost` owns the dedup: stale epochs and already-dead
        // shards no-op, probation deaths fall back without a loss
        shard_lost(&self.shared, tag.shard, tag.epoch, &cause);
    }

    fn on_timer(&mut self, ctl: &mut Ctl<'_>, key: u64) {
        if key != HEARTBEAT_TIMER {
            return;
        }
        // one `monitor_loop` body: ping, stall-probe, expire — then
        // reschedule. `closing` ends the cadence with no reschedule.
        let shared = Arc::clone(&self.shared);
        let heartbeat = shared.opts.health.heartbeat;
        struct Target {
            shard: usize,
            epoch: u64,
            data: Option<Token>,
            ctrl: Option<Token>,
            want_stats: bool,
        }
        let (seq, stats_seq, targets) = {
            let mut st = shared.lock();
            if st.closing {
                return;
            }
            st.ping_seq += 1;
            st.stats_want += 1;
            // prune identities their epoch has outrun (closed through
            // the handle, so no `on_close` removed them)
            self.tokens.retain(|_, t| {
                st.epoch.get(t.shard).copied() == Some(t.epoch)
            });
            let targets: Vec<Target> = st
                .health
                .ping_targets()
                .into_iter()
                .map(|i| Target {
                    shard: i,
                    epoch: st.epoch[i],
                    data: st.data_token[i],
                    ctrl: st.ctrl_token[i],
                    // poll full snapshots until this epoch's delta
                    // stream starts (threaded nodes never push one)
                    want_stats: st.delta_epoch[i] != st.epoch[i],
                })
                .collect();
            (st.ping_seq, st.stats_want, targets)
        };
        let ping = Msg::Ping { seq }.encode();
        let stats_req = Msg::StatsReq { seq: stats_seq }.encode();
        let mut lost: Vec<(usize, u64, String)> = Vec::new();
        for t in &targets {
            // liveness pings ride the control plane (or the data
            // connection's ctrl-priority lane when the plane is off).
            // A shard mid-registration has no token yet: skip it —
            // expiry covers a handoff that never completes.
            let ping_tok = if shared.opts.control_plane {
                t.ctrl
            } else {
                t.data
            };
            if let Some(tok) = ping_tok {
                if let Err(e) = ctl.send_ctrl(tok, &ping) {
                    lost.push((t.shard, t.epoch,
                               format!("heartbeat write failed: {e}")));
                    continue;
                }
                if t.want_stats {
                    let _ = ctl.send_ctrl(tok, &stats_req);
                }
            }
            if shared.opts.control_plane {
                if let Some(tok) = t.data {
                    if let Err(e) = ctl.send_ctrl(tok, &ping) {
                        lost.push((t.shard, t.epoch,
                                   format!("data-plane heartbeat \
                                            write failed: {e}")));
                    }
                }
            }
        }
        // stall probe: the reactor's own read counter replaces the
        // threaded path's `CountingReader` watermark (it resets per
        // connection, which reads as progress — correct: a fresh
        // connection gets a fresh clock)
        if shared.opts.control_plane {
            let stall = data_stall_deadline(shared.opts.health.timeout);
            let stalled: Vec<(usize, u64)> = {
                let mut st = shared.lock();
                let now = Instant::now();
                let mut out = Vec::new();
                for i in st.health.serving_indices() {
                    let Some(tok) = st.data_token[i] else { continue };
                    let bytes = ctl.bytes_in(tok);
                    let (last_bytes, since) = st.data_progress[i];
                    if bytes != last_bytes || st.inflight[i] == 0 {
                        st.data_progress[i] = (bytes, now);
                    } else if now.saturating_duration_since(since)
                        > stall
                    {
                        out.push((i, st.epoch[i]));
                    }
                }
                out
            };
            for (i, ep) in stalled {
                lost.push((i, ep,
                           format!("data plane stalled: requests in \
                                    flight but zero bytes read for \
                                    > {stall:?}")));
            }
        }
        let expired: Vec<(usize, u64)> = {
            let mut st = shared.lock();
            let now = Instant::now();
            st.health.tick(now);
            st.health
                .expired(now)
                .into_iter()
                .map(|i| (i, st.epoch[i]))
                .collect()
        };
        let timeout = shared.opts.health.timeout;
        for (i, ep) in expired {
            lost.push((i, ep,
                       format!("heartbeat timeout (> {timeout:?})")));
        }
        for (i, ep, cause) in lost {
            shard_lost(&shared, i, ep, &cause);
        }
        ctl.set_timer(ctl.now() + heartbeat, HEARTBEAT_TIMER);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::net::node::NodeOpts;
    use crate::serve::net::proto::WIRE_BINARY;
    use crate::serve::net::testutil::{
        mock_node, mock_node_at, mock_node_opts,
    };
    use crate::serve::net::wire::read_frame;
    use std::net::TcpListener;

    /// Fast heartbeats so pongs flow promptly, but a *generous*
    /// timeout: every death these tests exercise is detected via the
    /// severed connection (instant), and a tight timeout would let a
    /// loaded CI runner's scheduling stalls kill healthy mock nodes.
    /// Reconnection is effectively off (1 h) so death stays permanent
    /// unless a test opts in.
    fn fast_opts() -> ClusterOpts {
        ClusterOpts {
            health: HealthPolicy {
                heartbeat: Duration::from_millis(20),
                timeout: Duration::from_secs(5),
                ..HealthPolicy::default()
            },
            reconnect: Duration::from_secs(3600),
            ..ClusterOpts::default()
        }
    }

    /// Opts for the elasticity tests: prompt reconnects, a short pong
    /// streak, and the same stall-tolerant timeout.
    fn elastic_opts() -> ClusterOpts {
        ClusterOpts {
            health: HealthPolicy {
                heartbeat: Duration::from_millis(10),
                timeout: Duration::from_secs(5),
                readmit_pongs: 2,
            },
            reconnect: Duration::from_millis(30),
            ..ClusterOpts::default()
        }
    }

    fn recv_ok(rx: &Receiver<GenResult>) -> GenResponse {
        rx.recv_timeout(Duration::from_secs(20))
            .expect("no hang")
            .expect("request must succeed")
    }

    /// Poll until the cluster reports `n` serving shards (readmission
    /// and loss detection are asynchronous).
    fn wait_live_shards(cluster: &Cluster, n: usize, what: &str) {
        let deadline = Instant::now() + Duration::from_secs(15);
        while cluster.live_shards() != n {
            assert!(Instant::now() < deadline,
                    "{what}: still {} serving shard(s), want {n}",
                    cluster.live_shards());
            std::thread::sleep(Duration::from_millis(5));
        }
    }

    #[test]
    fn two_nodes_serve_mixed_load_with_exact_routing() {
        // a small per-slot delay keeps work in flight while the submit
        // loop runs, so the in-flight placement estimate alternates
        // shards deterministically
        let (node_a, addr_a) =
            mock_node(vec![1, 2, 4], 3, Duration::from_millis(2));
        let (node_b, addr_b) =
            mock_node(vec![1, 2, 4], 3, Duration::from_millis(2));
        let cluster = Cluster::connect(
            &[addr_a.to_string(), addr_b.to_string()],
            fast_opts(),
        )
        .unwrap();
        let mut rxs = Vec::new();
        let mut total = 0usize;
        for i in 0..12usize {
            let n = 1 + i % 4;
            total += n;
            let class = (i % 7) as i32;
            let (_, rx) =
                cluster.submit(GenRequest { class, n }).unwrap();
            rxs.push((class, n, rx));
        }
        for (class, n, rx) in rxs {
            let resp = recv_ok(&rx);
            assert_eq!(resp.images.len(), n * 3);
            assert!(
                resp.images.iter().all(|&p| p == class as f32),
                "cross-shard pixel mixup for class {class}"
            );
        }
        let agg = cluster.shutdown();
        assert_eq!(agg.requests, 12);
        assert_eq!(agg.failed_requests, 0);
        assert_eq!(agg.nodes_lost, 0);
        // node-side compute counters aggregated over both shards
        assert_eq!(agg.images as usize, total);
        assert_eq!(agg.pending, 0);
        assert_eq!(agg.enqueued,
                   agg.dispatched + agg.purged + agg.pending);
        let st_a = node_a.shutdown();
        let st_b = node_b.shutdown();
        // placement spread work across both shards
        assert!(st_a.requests > 0 && st_b.requests > 0,
                "one shard starved: {} / {}", st_a.requests,
                st_b.requests);
        // cluster aggregate == sum of per-node shutdown stats for the
        // compute counters
        assert_eq!(st_a.images + st_b.images, agg.images);
        let mut summed = st_a.clone();
        summed.absorb(&st_b);
        assert_eq!(summed.enqueued,
                   summed.dispatched + summed.purged + summed.pending);
    }

    #[test]
    fn severed_node_requeues_inflight_to_survivor() {
        // slow backend holds work in flight long enough to sever under
        // load deterministically
        let (node_a, addr_a) =
            mock_node(vec![1, 2, 4], 2, Duration::from_millis(20));
        let (node_b, addr_b) =
            mock_node(vec![1, 2, 4], 2, Duration::from_millis(20));
        let cluster = Cluster::connect(
            &[addr_a.to_string(), addr_b.to_string()],
            fast_opts(),
        )
        .unwrap();
        let mut rxs = Vec::new();
        for i in 0..8usize {
            let class = (1 + i % 5) as i32;
            let (_, rx) =
                cluster.submit(GenRequest { class, n: 2 }).unwrap();
            rxs.push((class, rx));
        }
        // both shards now hold queued work (placement alternates on
        // the in-flight estimate); partition shard A mid-load
        std::thread::sleep(Duration::from_millis(5));
        node_a.sever_connections();
        for (class, rx) in rxs {
            let resp = recv_ok(&rx);
            assert_eq!(resp.images.len(), 2 * 2);
            assert!(resp.images.iter().all(|&p| p == class as f32));
        }
        let agg = cluster.shutdown();
        assert_eq!(agg.requests, 8);
        assert_eq!(agg.failed_requests, 0, "re-queue must be invisible");
        assert_eq!(agg.nodes_lost, 1);
        assert!(agg.requeued >= 1,
                "shard A held in-flight work when severed");
        // the dead shard is out of the aggregate; the survivor's
        // conservation identity still holds over the sum
        assert_eq!(agg.enqueued,
                   agg.dispatched + agg.purged + agg.pending);
        // per-node conservation also holds on the severed node, which
        // kept draining its dispatched work after the partition
        let st_a = node_a.shutdown();
        assert_eq!(st_a.enqueued,
                   st_a.dispatched + st_a.purged + st_a.pending);
        node_b.shutdown();
    }

    #[test]
    fn losing_every_node_fails_typed_never_hangs() {
        let (node, addr) =
            mock_node(vec![4], 2, Duration::from_millis(30));
        let cluster =
            Cluster::connect(&[addr.to_string()], fast_opts()).unwrap();
        let (_, rx) =
            cluster.submit(GenRequest { class: 1, n: 4 }).unwrap();
        std::thread::sleep(Duration::from_millis(5));
        node.sever_connections();
        match rx.recv_timeout(Duration::from_secs(20)).expect("no hang") {
            Err(ServeError::NodeLost { cause }) => {
                assert!(cause.contains(&addr.to_string()), "{cause}");
            }
            other => panic!("expected NodeLost, got {other:?}"),
        }
        // later submits fail fast with the recorded cause (reconnects
        // are off in fast_opts, so the death is effectively permanent)
        match cluster.submit(GenRequest { class: 0, n: 1 }) {
            Err(ServeError::NodeLost { .. }) => {}
            other => panic!("expected NodeLost reject, got {other:?}"),
        }
        let agg = cluster.shutdown();
        assert_eq!(agg.nodes_lost, 1);
        assert_eq!(agg.failed_requests, 1);
        node.shutdown();
    }

    #[test]
    fn silent_shard_is_timed_out_and_its_load_rehomed() {
        // a listener that accepts nothing: connects succeed (kernel
        // backlog) but no pong ever comes back
        let silent = TcpListener::bind("127.0.0.1:0").unwrap();
        let silent_addr = silent.local_addr().unwrap();
        let (node, addr) = mock_node(vec![1, 2, 4], 2, Duration::ZERO);
        // this test is the one that needs expiry itself to fire, so it
        // runs a shorter (but still stall-tolerant) timeout
        let cluster = Cluster::connect(
            &[silent_addr.to_string(), addr.to_string()],
            ClusterOpts {
                health: HealthPolicy {
                    heartbeat: Duration::from_millis(20),
                    timeout: Duration::from_millis(600),
                    ..HealthPolicy::default()
                },
                reconnect: Duration::from_secs(3600),
                ..ClusterOpts::default()
            },
        )
        .unwrap();
        // shard 0 (silent, reported depth 0) wins the first pick: its
        // requests must be re-homed once the heartbeat timeout fires
        let mut rxs = Vec::new();
        for i in 0..4usize {
            let class = (i % 3) as i32 + 1;
            let (_, rx) =
                cluster.submit(GenRequest { class, n: 1 }).unwrap();
            rxs.push((class, rx));
        }
        for (class, rx) in rxs {
            let resp = recv_ok(&rx);
            assert!(resp.images.iter().all(|&p| p == class as f32));
        }
        let agg = cluster.shutdown();
        assert_eq!(agg.requests, 4);
        assert_eq!(agg.failed_requests, 0);
        assert_eq!(agg.nodes_lost, 1, "the silent shard must time out");
        assert!(agg.requeued >= 1, "the silent shard got the first pick");
        node.shutdown();
        drop(silent);
    }

    #[test]
    fn busy_node_with_huge_responses_is_not_declared_dead() {
        // The headline regression: multi-MiB response frames + a
        // liveness deadline far below their transfer/parse time. On
        // the pre-isolation single-connection path the pong queued
        // behind the response bytes and a merely *busy* node was
        // declared dead; with the control plane isolated (and data
        // frames chunked) liveness must stay green throughout.
        let il = 300_000usize; // ~0.6–1.2 MiB of JSON per image pair
        let (node, addr) =
            mock_node(vec![1, 2], il, Duration::from_millis(50));
        let cluster = Cluster::connect(
            &[addr.to_string()],
            ClusterOpts {
                health: HealthPolicy {
                    heartbeat: Duration::from_millis(20),
                    timeout: Duration::from_millis(1000),
                    ..HealthPolicy::default()
                },
                reconnect: Duration::from_secs(3600),
                ..ClusterOpts::default()
            },
        )
        .unwrap();
        let mut rxs = Vec::new();
        for i in 0..4usize {
            let class = (i % 3) as i32 + 1;
            let (_, rx) =
                cluster.submit(GenRequest { class, n: 2 }).unwrap();
            rxs.push((class, rx));
        }
        for (class, rx) in rxs {
            let resp = rx
                .recv_timeout(Duration::from_secs(60))
                .expect("no hang")
                .expect("busy node must keep serving");
            assert_eq!(resp.images.len(), 2 * il);
            assert!(resp.images.iter().all(|&p| p == class as f32));
        }
        let agg = cluster.shutdown();
        assert_eq!(agg.nodes_lost, 0,
                   "busy-but-healthy node was falsely declared dead");
        assert_eq!(agg.failed_requests, 0);
        assert_eq!(agg.requests, 4);
        node.shutdown();
    }

    #[test]
    fn shared_connection_mode_still_serves() {
        // --control-plane false: the pre-isolation topology (one
        // connection per shard, heartbeats ride the data plane) must
        // keep serving — it is the diagnostic baseline the isolation
        // fix is A/B-ed against (same build both ends; the flag is
        // not a cross-version compatibility mode)
        let (node, addr) = mock_node(vec![1, 2, 4], 3, Duration::ZERO);
        let cluster = Cluster::connect(
            &[addr.to_string()],
            ClusterOpts { control_plane: false, ..fast_opts() },
        )
        .unwrap();
        let mut rxs = Vec::new();
        for i in 0..4usize {
            let class = (i % 3) as i32;
            let (_, rx) =
                cluster.submit(GenRequest { class, n: 2 }).unwrap();
            rxs.push((class, rx));
        }
        for (class, rx) in rxs {
            let resp = recv_ok(&rx);
            assert!(resp.images.iter().all(|&p| p == class as f32));
        }
        let agg = cluster.shutdown();
        assert_eq!(agg.requests, 4);
        assert_eq!(agg.failed_requests, 0);
        assert_eq!(agg.nodes_lost, 0);
        node.shutdown();
    }

    #[test]
    fn severed_node_is_readmitted_and_serves_again() {
        let (node, addr) = mock_node(vec![1, 2, 4], 2, Duration::ZERO);
        let cluster = Cluster::connect(&[addr.to_string()],
                                       elastic_opts())
            .unwrap();
        let (_, rx) =
            cluster.submit(GenRequest { class: 1, n: 1 }).unwrap();
        recv_ok(&rx);
        // partition: the shard dies (read error) — but the node is
        // still listening, so the reconnector revives it and the pong
        // streak re-admits it. Polling the readmission counter (not a
        // transient live_shards dip) keeps this stall-tolerant.
        node.sever_connections();
        let deadline = Instant::now() + Duration::from_secs(15);
        while cluster.nodes_readmitted() == 0 {
            assert!(Instant::now() < deadline,
                    "severed node never re-admitted");
            std::thread::sleep(Duration::from_millis(5));
        }
        wait_live_shards(&cluster, 1, "after reconnect");
        let (_, rx) =
            cluster.submit(GenRequest { class: 3, n: 2 }).unwrap();
        let resp = recv_ok(&rx);
        assert!(resp.images.iter().all(|&p| p == 3.0),
                "re-admitted shard must serve real traffic");
        let agg = cluster.shutdown();
        assert_eq!(agg.nodes_lost, 1);
        assert_eq!(agg.nodes_readmitted, 1);
        assert_eq!(agg.failed_requests, 0);
        let st = node.shutdown();
        assert_eq!(st.requests, 2);
    }

    #[test]
    fn restarted_node_is_readmitted_without_restarting_the_frontend() {
        let (node, addr) = mock_node(vec![1, 2, 4], 2, Duration::ZERO);
        let cluster = Cluster::connect(&[addr.to_string()],
                                       elastic_opts())
            .unwrap();
        let (_, rx) =
            cluster.submit(GenRequest { class: 2, n: 1 }).unwrap();
        recv_ok(&rx);
        // full node death: process gone, listener gone
        node.shutdown();
        wait_live_shards(&cluster, 0, "after node shutdown");
        // a *new* node process comes up on the same address (bind may
        // briefly race the old listener's close)
        let node2 = {
            let deadline = Instant::now() + Duration::from_secs(10);
            loop {
                match mock_node_at(&addr.to_string(), vec![1, 2, 4], 2,
                                   Duration::ZERO) {
                    Ok(node2) => break node2,
                    Err(e) => {
                        assert!(Instant::now() < deadline,
                                "could not rebind the node address: \
                                 {e:#}");
                        std::thread::sleep(Duration::from_millis(50));
                    }
                }
            }
        };
        wait_live_shards(&cluster, 1, "after node restart");
        let (_, rx) =
            cluster.submit(GenRequest { class: 4, n: 2 }).unwrap();
        let resp = recv_ok(&rx);
        assert!(resp.images.iter().all(|&p| p == 4.0));
        let agg = cluster.shutdown();
        assert_eq!(agg.nodes_lost, 1);
        assert_eq!(agg.nodes_readmitted, 1);
        let st2 = node2.shutdown();
        assert_eq!(st2.requests, 1,
                   "restarted node must receive new placements");
    }

    #[test]
    fn dropped_cluster_fails_pending_typed_with_books_balanced() {
        // drop (not shutdown) with work in flight: the client gets a
        // typed ShuttingDown, and the drop path runs the same
        // in-flight bookkeeping as shutdown (the satellite fix — it
        // used to leak `inflight` slots)
        let (node, addr) =
            mock_node(vec![4], 2, Duration::from_millis(50));
        let cluster =
            Cluster::connect(&[addr.to_string()], fast_opts()).unwrap();
        let (_, rx) =
            cluster.submit(GenRequest { class: 1, n: 4 }).unwrap();
        drop(cluster);
        match rx.recv_timeout(Duration::from_secs(20)).expect("no hang") {
            Err(ServeError::ShuttingDown) => {}
            other => panic!("expected ShuttingDown, got {other:?}"),
        }
        node.shutdown();
    }

    #[test]
    fn cluster_backpressure_is_typed() {
        let (node, addr) =
            mock_node(vec![4], 2, Duration::from_millis(50));
        let cluster = Cluster::connect(
            &[addr.to_string()],
            ClusterOpts { max_queue: 4, ..fast_opts() },
        )
        .unwrap();
        let err =
            cluster.submit(GenRequest { class: 0, n: 5 }).unwrap_err();
        assert!(matches!(err,
                         ServeError::RequestTooLarge { n: 5, cap: 4 }));
        let (_, rx) =
            cluster.submit(GenRequest { class: 1, n: 3 }).unwrap();
        let err =
            cluster.submit(GenRequest { class: 2, n: 2 }).unwrap_err();
        assert!(matches!(err,
                         ServeError::QueueFull { queued: 3, cap: 4 }));
        recv_ok(&rx);
        cluster.shutdown();
        node.shutdown();
    }

    #[test]
    fn zero_image_request_completes_without_wire_traffic() {
        let (node, addr) = mock_node(vec![2], 2, Duration::ZERO);
        let cluster =
            Cluster::connect(&[addr.to_string()], fast_opts()).unwrap();
        let (id, rx) =
            cluster.submit(GenRequest { class: 1, n: 0 }).unwrap();
        let resp = recv_ok(&rx);
        assert_eq!(resp.id, id);
        assert!(resp.images.is_empty());
        cluster.shutdown();
        node.shutdown();
    }

    #[test]
    fn connect_to_nothing_errors() {
        // a bound-then-dropped listener gives a port that refuses
        let addr = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap()
        };
        let err = Cluster::connect(&[addr.to_string()], fast_opts())
            .unwrap_err();
        assert!(format!("{err:#}").contains("no shard node reachable"),
                "{err:#}");
        assert!(Cluster::connect(&[], fast_opts()).is_err());
    }

    // -- tracing + latency plumbing ------------------------------------

    #[test]
    fn clustered_trace_stitches_one_timeline_across_the_wire() {
        trace::set_enabled(true);
        let (node, addr) =
            mock_node(vec![1, 2, 4], 2, Duration::from_millis(1));
        let cluster =
            Cluster::connect(&[addr.to_string()], fast_opts())
                .unwrap();
        // one warm-up round trip: the HelloAck recording the node's
        // wire level is ordered before any response on the same
        // connection, so the next submit surely carries its trace
        let (_, rx) =
            cluster.submit(GenRequest { class: 0, n: 1 }).unwrap();
        recv_ok(&rx);
        let parent = TraceCtx {
            trace: trace::next_id(),
            span: trace::next_id(),
        };
        let (_, rx) = cluster
            .submit_traced(GenRequest { class: 2, n: 2 }, parent)
            .unwrap();
        recv_ok(&rx);
        let spans = trace::spans_for_trace(parent.trace);
        let root = spans
            .iter()
            .find(|r| {
                r.kind == SpanKind::Request
                    && r.parent == parent.span
            })
            .expect("frontend request root");
        let dispatch = spans
            .iter()
            .find(|r| r.kind == SpanKind::Dispatch)
            .expect("dispatch hop span");
        assert_eq!(dispatch.parent, root.span,
                   "the hop must hang off the request root");
        let node_root = spans
            .iter()
            .find(|r| {
                r.kind == SpanKind::Request
                    && r.parent == dispatch.span
            })
            .expect("node-side root must stitch under the dispatch \
                     hop");
        assert!(spans.iter().any(|r| r.kind == SpanKind::Generate),
                "node compute spans must ship home");
        // the re-based node timeline nests inside the hop window
        assert!(node_root.start_ns >= dispatch.start_ns);
        assert!(node_root.start_ns + node_root.dur_ns
                    <= dispatch.start_ns + dispatch.dur_ns,
                "node span must not spill past the dispatch hop");
        cluster.shutdown();
        node.shutdown();
    }

    /// One connection of a wire-v3 peer: acknowledges *below*
    /// [`WIRE_TRACE`] and answers the minimum protocol, recording the
    /// trace ctx of every submit it sees.
    fn old_wire_conn(mut stream: TcpStream,
                     seen: Arc<Mutex<Vec<TraceCtx>>>) {
        loop {
            let Ok(payload) = read_frame(&mut stream) else { return };
            let Ok(msg) = Msg::decode(&payload) else { return };
            let reply = match msg {
                Msg::Hello { .. } => {
                    Some(Msg::HelloAck { wire: WIRE_BINARY })
                }
                Msg::Ping { seq } => Some(Msg::Pong {
                    seq,
                    queue_depth: 0,
                    live_workers: 1,
                    ready_workers: 1,
                }),
                Msg::StatsReq { seq } => Some(Msg::Stats {
                    seq,
                    stats: ServerStats::default(),
                }),
                Msg::Submit { id, class, n, trace } => {
                    crate::util::lock(&seen).push(trace);
                    Some(Msg::Response {
                        id,
                        latency_s: 0.0,
                        images: vec![class as f32; n * 2],
                        spans: Vec::new(),
                    })
                }
                _ => None,
            };
            if let Some(r) = reply {
                if write_frame(&mut stream, &r.encode()).is_err() {
                    return;
                }
            }
        }
    }

    #[test]
    fn trace_ids_stay_home_below_the_trace_wire() {
        trace::set_enabled(true);
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let seen: Arc<Mutex<Vec<TraceCtx>>> =
            Arc::new(Mutex::new(Vec::new()));
        let accept_seen = Arc::clone(&seen);
        let server = std::thread::spawn(move || {
            // the frontend dials a data and a control connection
            let handlers: Vec<_> = (0..2)
                .map(|_| {
                    let (stream, _) =
                        listener.accept().expect("accept");
                    let seen = Arc::clone(&accept_seen);
                    std::thread::spawn(move || {
                        old_wire_conn(stream, seen)
                    })
                })
                .collect();
            for h in handlers {
                let _ = h.join();
            }
        });
        let cluster =
            Cluster::connect(&[addr.to_string()], fast_opts())
                .unwrap();
        // warm up one round trip so the (old) ack surely landed
        let (_, rx) =
            cluster.submit(GenRequest { class: 1, n: 1 }).unwrap();
        recv_ok(&rx);
        let parent = TraceCtx {
            trace: trace::next_id(),
            span: trace::next_id(),
        };
        let (_, rx) = cluster
            .submit_traced(GenRequest { class: 3, n: 2 }, parent)
            .unwrap();
        let resp = recv_ok(&rx);
        assert_eq!(resp.images.len(), 2 * 2);
        // the old peer never saw a trace id...
        for t in crate::util::lock(&seen).iter() {
            assert_eq!(*t, TraceCtx::NONE,
                       "trace ids must not cross a wire below \
                        WIRE_TRACE");
        }
        // ...but the frontend half of the timeline still recorded
        let spans = trace::spans_for_trace(parent.trace);
        assert!(spans.iter().any(|r| r.kind == SpanKind::Request),
                "frontend request root missing");
        assert!(spans.iter().any(|r| r.kind == SpanKind::Dispatch),
                "frontend dispatch span missing");
        cluster.shutdown();
        server.join().unwrap();
    }

    #[test]
    fn stats_fold_rebuilds_the_latency_histogram_from_delta_pushes() {
        // a node's cumulative histogram at two push instants
        let mut c1 = LatencyHist::new();
        for _ in 0..40 {
            c1.record(0.010);
        }
        let mut c2 = c1.clone();
        for _ in 0..10 {
            c2.record(1.0);
        }
        // push 1 = full cumulative values (the first push on a
        // connection), push 2 = per-bucket increment
        let mut push1 = ServerStats::default();
        push1.latency = c1.clone();
        let mut push2 = ServerStats::default();
        push2.latency = c2.delta_since(&c1);
        let folded = stats_fold(&push1, &push2);
        assert_eq!(folded.latency.count(), c2.count());
        assert_eq!(folded.latency.quantile(0.95), c2.quantile(0.95));
        assert!(folded.latency_p95_s > 0.9,
                "p95 must see the slow tail from the second push");
        assert!(folded.latency_p50_s < 0.02,
                "p50 must stay with the fast mass");
    }

    // -- reactor-mode frontend -----------------------------------------

    /// [`fast_opts`] on the reactor transport.
    fn reactor_opts() -> ClusterOpts {
        ClusterOpts { reactor: true, ..fast_opts() }
    }

    /// A reactor-mode node with a prompt stats-push cadence.
    fn reactor_node_opts() -> NodeOpts {
        NodeOpts {
            reactor: true,
            stats_push: Duration::from_millis(20),
            ..NodeOpts::default()
        }
    }

    #[test]
    fn reactor_cluster_serves_mixed_load_end_to_end() {
        // both ends event-driven: reactor frontend, reactor nodes,
        // binary response payloads negotiated on every data plane
        let (node_a, addr_a) = mock_node_opts(
            vec![1, 2, 4], 3, Duration::from_millis(2),
            reactor_node_opts());
        let (node_b, addr_b) = mock_node_opts(
            vec![1, 2, 4], 3, Duration::from_millis(2),
            reactor_node_opts());
        let cluster = Cluster::connect(
            &[addr_a.to_string(), addr_b.to_string()],
            reactor_opts(),
        )
        .unwrap();
        let mut rxs = Vec::new();
        let mut total = 0usize;
        for i in 0..12usize {
            let n = 1 + i % 4;
            total += n;
            let class = (i % 7) as i32;
            let (_, rx) =
                cluster.submit(GenRequest { class, n }).unwrap();
            rxs.push((class, n, rx));
        }
        for (class, n, rx) in rxs {
            let resp = recv_ok(&rx);
            assert_eq!(resp.images.len(), n * 3);
            assert!(
                resp.images.iter().all(|&p| p == class as f32),
                "cross-shard pixel mixup for class {class}"
            );
        }
        let agg = cluster.shutdown();
        assert_eq!(agg.requests, 12);
        assert_eq!(agg.failed_requests, 0);
        assert_eq!(agg.nodes_lost, 0);
        assert_eq!(agg.images as usize, total);
        assert_eq!(agg.enqueued,
                   agg.dispatched + agg.purged + agg.pending);
        let st_a = node_a.shutdown();
        let st_b = node_b.shutdown();
        assert!(st_a.requests > 0 && st_b.requests > 0,
                "one shard starved: {} / {}", st_a.requests,
                st_b.requests);
        assert_eq!(st_a.images + st_b.images, agg.images);
    }

    #[test]
    fn reactor_severed_node_requeues_inflight_to_survivor() {
        // the PR 5 re-queue regression on the reactor path (threaded
        // nodes on purpose: the matrix's mixed half)
        let (node_a, addr_a) =
            mock_node(vec![1, 2, 4], 2, Duration::from_millis(20));
        let (node_b, addr_b) =
            mock_node(vec![1, 2, 4], 2, Duration::from_millis(20));
        let cluster = Cluster::connect(
            &[addr_a.to_string(), addr_b.to_string()],
            reactor_opts(),
        )
        .unwrap();
        let mut rxs = Vec::new();
        for i in 0..8usize {
            let class = (1 + i % 5) as i32;
            let (_, rx) =
                cluster.submit(GenRequest { class, n: 2 }).unwrap();
            rxs.push((class, rx));
        }
        std::thread::sleep(Duration::from_millis(5));
        node_a.sever_connections();
        for (class, rx) in rxs {
            let resp = recv_ok(&rx);
            assert_eq!(resp.images.len(), 2 * 2);
            assert!(resp.images.iter().all(|&p| p == class as f32));
        }
        let agg = cluster.shutdown();
        assert_eq!(agg.requests, 8);
        assert_eq!(agg.failed_requests, 0, "re-queue must be invisible");
        assert_eq!(agg.nodes_lost, 1);
        assert!(agg.requeued >= 1,
                "shard A held in-flight work when severed");
        assert_eq!(agg.enqueued,
                   agg.dispatched + agg.purged + agg.pending);
        let st_a = node_a.shutdown();
        assert_eq!(st_a.enqueued,
                   st_a.dispatched + st_a.purged + st_a.pending);
        node_b.shutdown();
    }

    #[test]
    fn reactor_busy_node_with_huge_responses_is_not_declared_dead() {
        // the PR 5 headline regression, reactor path: multi-MiB
        // responses with a liveness deadline far below their transfer
        // time must not read as death
        let il = 300_000usize;
        let (node, addr) =
            mock_node(vec![1, 2], il, Duration::from_millis(50));
        let cluster = Cluster::connect(
            &[addr.to_string()],
            ClusterOpts {
                health: HealthPolicy {
                    heartbeat: Duration::from_millis(20),
                    timeout: Duration::from_millis(1000),
                    ..HealthPolicy::default()
                },
                reconnect: Duration::from_secs(3600),
                reactor: true,
                ..ClusterOpts::default()
            },
        )
        .unwrap();
        let mut rxs = Vec::new();
        for i in 0..4usize {
            let class = (i % 3) as i32 + 1;
            let (_, rx) =
                cluster.submit(GenRequest { class, n: 2 }).unwrap();
            rxs.push((class, rx));
        }
        for (class, rx) in rxs {
            let resp = rx
                .recv_timeout(Duration::from_secs(60))
                .expect("no hang")
                .expect("busy node must keep serving");
            assert_eq!(resp.images.len(), 2 * il);
            assert!(resp.images.iter().all(|&p| p == class as f32));
        }
        let agg = cluster.shutdown();
        assert_eq!(agg.nodes_lost, 0,
                   "busy-but-healthy node was falsely declared dead");
        assert_eq!(agg.failed_requests, 0);
        assert_eq!(agg.requests, 4);
        node.shutdown();
    }

    #[test]
    fn reactor_severed_node_is_readmitted_and_serves_again() {
        // the flap cycle (lost → reconnect → probation → pong streak →
        // re-admitted → serving) driven by the reactor state machines
        let (node, addr) = mock_node(vec![1, 2, 4], 2, Duration::ZERO);
        let cluster = Cluster::connect(
            &[addr.to_string()],
            ClusterOpts { reactor: true, ..elastic_opts() },
        )
        .unwrap();
        let (_, rx) =
            cluster.submit(GenRequest { class: 1, n: 1 }).unwrap();
        recv_ok(&rx);
        node.sever_connections();
        let deadline = Instant::now() + Duration::from_secs(15);
        while cluster.nodes_readmitted() == 0 {
            assert!(Instant::now() < deadline,
                    "severed node never re-admitted");
            std::thread::sleep(Duration::from_millis(5));
        }
        wait_live_shards(&cluster, 1, "after reconnect");
        let (_, rx) =
            cluster.submit(GenRequest { class: 3, n: 2 }).unwrap();
        let resp = recv_ok(&rx);
        assert!(resp.images.iter().all(|&p| p == 3.0),
                "re-admitted shard must serve real traffic");
        let agg = cluster.shutdown();
        assert_eq!(agg.nodes_lost, 1);
        assert_eq!(agg.nodes_readmitted, 1);
        assert_eq!(agg.failed_requests, 0);
        let st = node.shutdown();
        assert_eq!(st.requests, 2);
    }

    #[test]
    fn reactor_stats_deltas_reconstruct_cumulative_counters() {
        // a reactor node pushes deltas unprompted; the folded stream
        // must converge on the node's cumulative counters with the
        // conservation identity intact — no snapshot polling involved
        let (node, addr) =
            mock_node_opts(vec![1, 2], 3, Duration::ZERO,
                           reactor_node_opts());
        let cluster =
            Cluster::connect(&[addr.to_string()], reactor_opts())
                .unwrap();
        for i in 0..5u64 {
            let (_, rx) = cluster
                .submit(GenRequest { class: (i % 3) as i32, n: 2 })
                .unwrap();
            recv_ok(&rx);
        }
        let deadline = Instant::now() + Duration::from_secs(15);
        loop {
            let agg = cluster.stats();
            if agg.images == 10 {
                assert_eq!(agg.enqueued,
                           agg.dispatched + agg.purged + agg.pending);
                break;
            }
            assert!(Instant::now() < deadline,
                    "delta stream never reached the cumulative count \
                     (images = {})", agg.images);
            std::thread::sleep(Duration::from_millis(5));
        }
        // the latency histogram rides the same delta stream: the
        // folded per-shard snapshot reconstructs the node's samples
        {
            let st = cluster.shared.lock();
            let hist = &st.last_stats[0]
                .as_ref()
                .expect("folded snapshot")
                .latency;
            assert_eq!(hist.count(), 5,
                       "one latency sample per request must survive \
                        the delta encoding");
        }
        // while the aggregate overlays the frontend's end-to-end view
        let agg = cluster.stats();
        assert_eq!(agg.latency.count(), 5);
        assert!(agg.latency_p95_s >= agg.latency_p50_s);
        cluster.shutdown();
        let st = node.shutdown();
        assert_eq!(st.images, 10);
    }

    #[test]
    fn reactor_shared_connection_mode_still_serves() {
        // --control-plane false on the reactor: heartbeats ride the
        // data connection's ctrl-priority lane
        let (node, addr) = mock_node(vec![1, 2, 4], 3, Duration::ZERO);
        let cluster = Cluster::connect(
            &[addr.to_string()],
            ClusterOpts { control_plane: false, ..reactor_opts() },
        )
        .unwrap();
        let mut rxs = Vec::new();
        for i in 0..4usize {
            let class = (i % 3) as i32;
            let (_, rx) =
                cluster.submit(GenRequest { class, n: 2 }).unwrap();
            rxs.push((class, rx));
        }
        for (class, rx) in rxs {
            let resp = recv_ok(&rx);
            assert!(resp.images.iter().all(|&p| p == class as f32));
        }
        let agg = cluster.shutdown();
        assert_eq!(agg.requests, 4);
        assert_eq!(agg.failed_requests, 0);
        assert_eq!(agg.nodes_lost, 0);
        node.shutdown();
    }
}
