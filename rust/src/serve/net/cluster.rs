//! Cluster frontend: the same submit/recv surface as a local server,
//! dispatched across remote shard nodes.
//!
//! A [`Cluster`] connects to N [`NodeServer`](super::node::NodeServer)
//! addresses and implements [`Dispatch`], so clients (and `serve_demo`,
//! and the CLI) cannot tell it from an in-process
//! [`GenServer`](crate::serve::GenServer):
//!
//! * **Placement** — each submit goes to the alive shard with the
//!   least load: the queue depth it reported in its last heartbeat
//!   plus the slots this frontend has in flight to it (covering the
//!   window before the next heartbeat reflects them). See
//!   [`Health::pick`].
//! * **Health** — a monitor thread pings every live shard each
//!   heartbeat interval; a shard that misses the timeout, or whose
//!   connection errors on read or write, is declared dead (permanently
//!   — restart the frontend to re-admit a recovered node).
//! * **Re-queue on node loss** — the in-flight requests of a dead
//!   shard are resubmitted to surviving shards (counted in
//!   [`ServerStats::requeued`]), reusing the same
//!   purge-and-repropagate semantics the router applies to a dead
//!   worker's batch. Only when *no* shard survives does a client see
//!   [`ServeError::NodeLost`] — otherwise node loss is invisible,
//!   modulo latency.
//! * **Stats** — shard nodes answer `StatsReq` with live
//!   [`ServerStats`] snapshots; the cluster aggregates them via
//!   [`ServerStats::absorb`] (so the batcher-conservation identity
//!   `enqueued == dispatched + purged + pending` keeps holding over
//!   the sum) and overlays what only it can see: cluster-level
//!   request/failure counts, *end-to-end* latency percentiles
//!   (queue + wire + compute, measured at the frontend), re-queues
//!   and lost nodes.
//!
//! Locking: the state mutex and the per-shard writer mutexes are never
//! held together — state decisions happen under the state lock, frame
//! writes after it is released — so a slow TCP write can not stall
//! submits, deliveries or the heartbeat monitor.

use std::collections::HashMap;
use std::net::TcpStream;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::serve::dispatch::Dispatch;
use crate::serve::error::ServeError;
use crate::serve::net::health::{Health, HealthPolicy};
use crate::serve::net::proto::Msg;
use crate::serve::net::wire::{read_frame, write_frame, WireError};
use crate::serve::router::{
    GenRequest, GenResponse, GenResult, ServerStats,
};
use crate::util::bench::percentile;
use crate::{debug_log, warn_log};

/// Cluster tuning knobs.
#[derive(Clone, Copy, Debug)]
pub struct ClusterOpts {
    /// Heartbeat cadence + node-loss deadline.
    pub health: HealthPolicy,
    /// Backpressure: reject submits once this many image slots are in
    /// flight across all shards (mirrors the router's queue cap).
    pub max_queue: usize,
}

impl Default for ClusterOpts {
    fn default() -> Self {
        ClusterOpts {
            health: HealthPolicy::default(),
            max_queue: 16384,
        }
    }
}

impl ClusterOpts {
    /// The one place the config's millisecond knobs become a health
    /// policy — the CLI, the demo and future callers must not each
    /// repeat this mapping.
    pub fn from_run_config(cfg: &crate::util::config::RunConfig)
                           -> ClusterOpts {
        ClusterOpts {
            health: HealthPolicy {
                heartbeat: Duration::from_millis(cfg.heartbeat_ms),
                timeout: Duration::from_millis(cfg.node_timeout_ms),
            },
            ..ClusterOpts::default()
        }
    }
}

/// One outstanding request (enough to resubmit it on node loss).
struct ClusterPending {
    class: i32,
    n: usize,
    tx: Sender<GenResult>,
    /// Shard currently responsible for it.
    shard: usize,
    t0: Instant,
}

struct ClusterState {
    open: bool,
    /// Deliberate teardown: connection drops are expected, not losses.
    closing: bool,
    health: Health,
    pending: HashMap<u64, ClusterPending>,
    /// Per-shard in-flight slot estimate (submitted minus answered).
    inflight: Vec<usize>,
    requests: u64,
    failed_requests: u64,
    requeued: u64,
    nodes_lost: u64,
    /// First recorded loss cause (attached to dead-cluster errors).
    first_cause: Option<String>,
    /// Ring of recent end-to-end latencies (completed requests only).
    latencies: Vec<f64>,
    latency_count: u64,
    /// Last stats snapshot + the request seq it answered, per shard.
    last_stats: Vec<Option<ServerStats>>,
    stats_seen: Vec<u64>,
    stats_want: u64,
    ping_seq: u64,
}

struct ClusterShared {
    addrs: Vec<String>,
    /// Write halves; `None` once the shard is dead (or being torn
    /// down). Never locked while holding the state mutex.
    writers: Vec<Mutex<Option<TcpStream>>>,
    state: Mutex<ClusterState>,
    /// Signaled on delivery, node loss, stats arrival and teardown.
    changed: Condvar,
    opts: ClusterOpts,
}

impl ClusterShared {
    fn lock(&self) -> std::sync::MutexGuard<'_, ClusterState> {
        self.state.lock().unwrap_or_else(|p| p.into_inner())
    }
}

/// Handle to the cross-node generation service. `Sync` like the local
/// router: any number of client threads submit through one reference.
pub struct Cluster {
    shared: Arc<ClusterShared>,
    next_id: AtomicU64,
    readers: Vec<JoinHandle<()>>,
    monitor: Option<JoinHandle<()>>,
    t_start: Instant,
}

impl Cluster {
    /// Connect to the shard nodes. Unreachable addresses start dead
    /// (logged); at least one must be reachable or this errors.
    pub fn connect(addrs: &[String], opts: ClusterOpts) -> Result<Cluster> {
        if addrs.is_empty() {
            bail!("cluster needs at least one shard address");
        }
        let now = Instant::now();
        let mut health = Health::new(addrs.len(), opts.health, now);
        let mut writers = Vec::with_capacity(addrs.len());
        let mut read_streams: Vec<Option<TcpStream>> =
            Vec::with_capacity(addrs.len());
        let mut nodes_lost = 0u64;
        let mut first_cause = None;
        for (i, addr) in addrs.iter().enumerate() {
            match TcpStream::connect(addr) {
                Ok(stream) => {
                    let _ = stream.set_nodelay(true);
                    // a shard that stops *reading* (wedged process,
                    // half-open partition) must fail the write with a
                    // typed error instead of blocking the writer mutex
                    // — a blocked mutex would stall the heartbeat
                    // monitor and every submit to that shard
                    let _ = stream.set_write_timeout(
                        Some(opts.health.timeout));
                    match stream.try_clone() {
                        Ok(reader) => {
                            read_streams.push(Some(reader));
                            writers.push(Mutex::new(Some(stream)));
                        }
                        Err(e) => {
                            warn_log!("cluster: shard {addr}: clone \
                                       failed: {e}");
                            health.mark_dead(i);
                            nodes_lost += 1;
                            first_cause.get_or_insert(format!(
                                "shard {addr}: {e}"));
                            read_streams.push(None);
                            writers.push(Mutex::new(None));
                        }
                    }
                }
                Err(e) => {
                    warn_log!("cluster: shard {addr} unreachable: {e}");
                    health.mark_dead(i);
                    nodes_lost += 1;
                    first_cause
                        .get_or_insert(format!("shard {addr}: {e}"));
                    read_streams.push(None);
                    writers.push(Mutex::new(None));
                }
            }
        }
        if health.alive_count() == 0 {
            bail!(
                "no shard node reachable ({})",
                first_cause.as_deref().unwrap_or("none configured")
            );
        }
        let n = addrs.len();
        let shared = Arc::new(ClusterShared {
            addrs: addrs.to_vec(),
            writers,
            state: Mutex::new(ClusterState {
                open: true,
                closing: false,
                health,
                pending: HashMap::new(),
                inflight: vec![0; n],
                requests: 0,
                failed_requests: 0,
                requeued: 0,
                nodes_lost,
                first_cause,
                latencies: Vec::new(),
                latency_count: 0,
                last_stats: vec![None; n],
                stats_seen: vec![0; n],
                stats_want: 0,
                ping_seq: 0,
            }),
            changed: Condvar::new(),
            opts,
        });
        let mut readers = Vec::new();
        for (i, stream) in read_streams.into_iter().enumerate() {
            let Some(stream) = stream else { continue };
            let rd_shared = Arc::clone(&shared);
            let h = std::thread::Builder::new()
                .name(format!("tqdit-net-read-{i}"))
                .spawn(move || reader_loop(rd_shared, i, stream))
                .context("spawning cluster reader thread")?;
            readers.push(h);
        }
        let mon_shared = Arc::clone(&shared);
        let monitor = std::thread::Builder::new()
            .name("tqdit-net-monitor".into())
            .spawn(move || monitor_loop(mon_shared))
            .context("spawning cluster monitor thread")?;
        Ok(Cluster {
            shared,
            next_id: AtomicU64::new(0),
            readers,
            monitor: Some(monitor),
            t_start: Instant::now(),
        })
    }

    /// Submit a request to the least-loaded alive shard. Same contract
    /// as the local router's `submit`; the one new failure mode is
    /// [`ServeError::NodeLost`] when no shard remains.
    pub fn submit(&self, req: GenRequest)
                  -> std::result::Result<(u64, Receiver<GenResult>),
                                         ServeError> {
        let shard;
        let id;
        let rx;
        {
            let mut st = self.shared.lock();
            if !st.open {
                return Err(ServeError::ShuttingDown);
            }
            if st.health.alive_count() == 0 {
                return Err(ServeError::NodeLost {
                    cause: st
                        .first_cause
                        .clone()
                        .unwrap_or_else(|| "no live shard nodes".into()),
                });
            }
            if req.n > self.shared.opts.max_queue {
                return Err(ServeError::RequestTooLarge {
                    n: req.n,
                    cap: self.shared.opts.max_queue,
                });
            }
            let queued: usize = st.inflight.iter().sum();
            if queued + req.n > self.shared.opts.max_queue {
                return Err(ServeError::QueueFull {
                    queued,
                    cap: self.shared.opts.max_queue,
                });
            }
            id = self.next_id.fetch_add(1, Ordering::Relaxed);
            st.requests += 1;
            let (tx, rx_) = channel();
            rx = rx_;
            if req.n == 0 {
                // nothing to compute: complete immediately, no wire
                let _ = tx.send(Ok(GenResponse {
                    id,
                    images: Vec::new(),
                    latency_s: 0.0,
                }));
                return Ok((id, rx));
            }
            shard = st
                .health
                .pick(&st.inflight)
                .expect("alive_count > 0 implies a pick");
            st.pending.insert(id, ClusterPending {
                class: req.class,
                n: req.n,
                tx,
                shard,
                t0: Instant::now(),
            });
            st.inflight[shard] += req.n;
        }
        // the wire write happens outside the state lock; on failure the
        // lost-node path re-queues (or typed-fails) this very request
        let msg = Msg::Submit { id, class: req.class, n: req.n };
        if let Err(cause) = send_to_shard(&self.shared, shard, &msg) {
            shard_lost(&self.shared, shard, &cause);
        }
        Ok((id, rx))
    }

    /// Slots submitted but not yet answered (local estimate).
    pub fn queue_depth(&self) -> usize {
        self.shared.lock().inflight.iter().sum()
    }

    /// Sum of live worker counts the alive shards last reported.
    pub fn live_workers(&self) -> usize {
        self.shared.lock().health.live_workers_total()
    }

    /// Sum of ready worker counts the alive shards last reported.
    pub fn ready_workers(&self) -> usize {
        self.shared.lock().health.ready_workers_total()
    }

    /// Shards still considered alive.
    pub fn live_shards(&self) -> usize {
        self.shared.lock().health.alive_count()
    }

    /// Aggregate of the latest shard snapshots + cluster-level
    /// overlay (see module docs). The monitor refreshes shard
    /// snapshots on the heartbeat cadence, so node-side counters are
    /// at most one interval stale; a shard that never answered (just
    /// connected, or dead before its first reply) contributes nothing
    /// yet.
    pub fn stats(&self) -> ServerStats {
        let st = self.shared.lock();
        aggregate(&st, self.t_start.elapsed().as_secs_f64())
    }

    /// Stop accepting, wait for in-flight requests to resolve (they
    /// complete on their shards, or fail typed when shards die), pull
    /// a final stats snapshot from every surviving shard, tear the
    /// connections down and return the aggregate.
    pub fn shutdown(mut self) -> ServerStats {
        {
            let mut st = self.shared.lock();
            st.open = false;
        }
        // 1. drain: in-flight work either completes on a live shard or
        // is failed typed by the lost-node path once the monitor (still
        // running) declares its shard dead — so this loop terminates.
        // A hard deadline bounds even a misbehaving-but-pinging shard.
        let patience = (self.shared.opts.health.timeout * 10)
            .max(Duration::from_secs(30));
        let deadline = Instant::now() + patience;
        {
            let mut st = self.shared.lock();
            while !st.pending.is_empty() {
                let now = Instant::now();
                if now >= deadline || st.health.alive_count() == 0 {
                    break;
                }
                let wait =
                    (deadline - now).min(Duration::from_millis(100));
                let (g, _) = self
                    .shared
                    .changed
                    .wait_timeout(st, wait)
                    .unwrap_or_else(|p| p.into_inner());
                st = g;
            }
            if !st.pending.is_empty() {
                let stranded: Vec<u64> =
                    st.pending.keys().copied().collect();
                warn_log!("cluster: shutdown with {} request(s) still \
                           unresolved; failing them typed",
                          stranded.len());
                for sid in stranded {
                    let p = st.pending.remove(&sid).unwrap();
                    st.inflight[p.shard] =
                        st.inflight[p.shard].saturating_sub(p.n);
                    st.failed_requests += 1;
                    let _ = p.tx.send(Err(ServeError::NodeLost {
                        cause: "cluster shut down with the request \
                                still in flight"
                            .into(),
                    }));
                }
            }
        }
        // 2. final stats sweep from the survivors
        let want = {
            let mut st = self.shared.lock();
            st.stats_want += 1;
            st.stats_want
        };
        let survivors = self.shared.lock().health.alive_indices();
        for i in survivors {
            if let Err(c) = send_to_shard(&self.shared, i,
                                          &Msg::StatsReq { seq: want }) {
                shard_lost(&self.shared, i,
                           &format!("stats request write failed: {c}"));
            }
        }
        {
            let stats_deadline =
                Instant::now() + self.shared.opts.health.timeout;
            let mut st = self.shared.lock();
            loop {
                let missing = st
                    .health
                    .alive_indices()
                    .into_iter()
                    .any(|i| st.stats_seen[i] < want);
                let now = Instant::now();
                if !missing || now >= stats_deadline {
                    break;
                }
                let (g, _) = self
                    .shared
                    .changed
                    .wait_timeout(st, stats_deadline - now)
                    .unwrap_or_else(|p| p.into_inner());
                st = g;
            }
        }
        // 3. teardown: expected closes from here on
        self.teardown();
        let st = self.shared.lock();
        aggregate(&st, self.t_start.elapsed().as_secs_f64())
    }

    /// Close every connection and join the reader/monitor threads
    /// (idempotent; shared between shutdown and drop).
    fn teardown(&mut self) {
        {
            let mut st = self.shared.lock();
            st.closing = true;
        }
        self.shared.changed.notify_all();
        for w in &self.shared.writers {
            let mut g = w.lock().unwrap_or_else(|p| p.into_inner());
            if let Some(s) = g.take() {
                let _ = s.shutdown(std::net::Shutdown::Both);
            }
        }
        for h in self.readers.drain(..) {
            let _ = h.join();
        }
        if let Some(h) = self.monitor.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Cluster {
    /// A cluster dropped without `shutdown` still tears its threads
    /// down; anything in flight is failed typed, never stranded.
    fn drop(&mut self) {
        {
            let mut st = self.shared.lock();
            st.open = false;
            let stranded: Vec<u64> = st.pending.keys().copied().collect();
            for sid in stranded {
                let p = st.pending.remove(&sid).unwrap();
                st.failed_requests += 1;
                let _ = p.tx.send(Err(ServeError::ShuttingDown));
            }
        }
        self.teardown();
    }
}

impl Dispatch for Cluster {
    fn submit(&self, req: GenRequest)
              -> std::result::Result<(u64, Receiver<GenResult>),
                                     ServeError> {
        Cluster::submit(self, req)
    }
    fn queue_depth(&self) -> usize {
        Cluster::queue_depth(self)
    }
    fn live_workers(&self) -> usize {
        Cluster::live_workers(self)
    }
    fn ready_workers(&self) -> usize {
        Cluster::ready_workers(self)
    }
    fn stats(&self) -> ServerStats {
        Cluster::stats(self)
    }
    fn shutdown(self: Box<Self>) -> ServerStats {
        Cluster::shutdown(*self)
    }
}

/// Aggregate shard snapshots + cluster overlay (state lock held by the
/// caller).
fn aggregate(st: &ClusterState, wall_s: f64) -> ServerStats {
    let mut agg = ServerStats::default();
    for s in st.last_stats.iter().flatten() {
        agg.absorb(s);
    }
    // what only the frontend can see: the client-facing request
    // counts, re-queue/loss accounting, and true end-to-end latency
    agg.requests = st.requests;
    agg.failed_requests = st.failed_requests;
    agg.requeued = st.requeued;
    agg.nodes_lost = st.nodes_lost;
    agg.wall_s = wall_s;
    let mut lat = st.latencies.clone();
    lat.sort_by(f64::total_cmp);
    agg.latency_p50_s = percentile(&lat, 0.50);
    agg.latency_p95_s = percentile(&lat, 0.95);
    agg
}

/// Write one frame to a shard (its writer mutex only; never the state
/// lock). `Err` carries the cause for the lost-node path.
fn send_to_shard(shared: &ClusterShared, shard: usize, msg: &Msg)
                 -> std::result::Result<(), String> {
    let mut g = shared.writers[shard]
        .lock()
        .unwrap_or_else(|p| p.into_inner());
    let Some(stream) = g.as_mut() else {
        return Err("connection already closed".into());
    };
    write_frame(stream, &msg.encode()).map_err(|e| e.to_string())
}

/// Deliver a terminal outcome for request `id` (from whichever shard
/// answered first — a request re-queued off a slow-but-alive shard may
/// legitimately resolve twice; the second is logged and dropped).
fn complete(shared: &ClusterShared, id: u64,
            outcome: std::result::Result<Vec<f32>, ServeError>) {
    let mut st = shared.lock();
    let Some(p) = st.pending.remove(&id) else {
        debug_log!("cluster: late/duplicate answer for request {id} \
                    dropped");
        return;
    };
    st.inflight[p.shard] = st.inflight[p.shard].saturating_sub(p.n);
    let latency_s = p.t0.elapsed().as_secs_f64();
    match outcome {
        Ok(images) => {
            crate::serve::router::push_latency(
                &mut st.latencies, &mut st.latency_count, latency_s);
            let _ = p.tx.send(Ok(GenResponse { id, images, latency_s }));
        }
        Err(err) => {
            st.failed_requests += 1;
            let _ = p.tx.send(Err(err));
        }
    }
    let drained = st.pending.is_empty();
    drop(st);
    if drained {
        shared.changed.notify_all();
    }
}

/// Declare a shard dead and re-home its in-flight requests: each is
/// resubmitted to the least-loaded survivor, or failed with a typed
/// [`ServeError::NodeLost`] when none remains. Runs the cleanup
/// exactly once per shard (`Health::mark_dead` gates re-entry);
/// resubmit write failures cascade iteratively, never recursively.
fn shard_lost(shared: &ClusterShared, shard: usize, cause: &str) {
    let mut work: Vec<(usize, String)> =
        vec![(shard, cause.to_string())];
    while let Some((i, cause)) = work.pop() {
        // close the socket first so the shard's reader thread unblocks
        {
            let mut g = shared.writers[i]
                .lock()
                .unwrap_or_else(|p| p.into_inner());
            if let Some(s) = g.take() {
                let _ = s.shutdown(std::net::Shutdown::Both);
            }
        }
        let mut resubmits: Vec<(usize, Msg)> = Vec::new();
        {
            let mut st = shared.lock();
            if !st.health.mark_dead(i) {
                continue; // already handled by a racing path
            }
            if st.closing {
                continue; // deliberate teardown, not a loss
            }
            st.nodes_lost += 1;
            // drop the dead shard's snapshot: its in-flight slots are
            // about to be re-enqueued (and so re-counted) on the
            // survivors, and a stale snapshot would double-count them
            // and report phantom `pending` forever
            st.last_stats[i] = None;
            let full_cause =
                format!("shard {}: {}", shared.addrs[i], cause);
            warn_log!("cluster: node lost — {full_cause}; re-queuing \
                       its in-flight requests");
            if st.first_cause.is_none() {
                st.first_cause = Some(full_cause.clone());
            }
            st.inflight[i] = 0;
            let moved: Vec<u64> = st
                .pending
                .iter()
                .filter(|(_, p)| p.shard == i)
                .map(|(&id, _)| id)
                .collect();
            for id in moved {
                match st.health.pick(&st.inflight) {
                    Some(j) => {
                        let p = st
                            .pending
                            .get_mut(&id)
                            .expect("collected from pending");
                        p.shard = j;
                        let (class, n) = (p.class, p.n);
                        st.inflight[j] += n;
                        st.requeued += 1;
                        resubmits
                            .push((j, Msg::Submit { id, class, n }));
                    }
                    None => {
                        let p = st
                            .pending
                            .remove(&id)
                            .expect("collected from pending");
                        st.failed_requests += 1;
                        let _ = p.tx.send(Err(ServeError::NodeLost {
                            cause: format!(
                                "{full_cause}; no surviving shard to \
                                 take the request"
                            ),
                        }));
                    }
                }
            }
        }
        shared.changed.notify_all();
        for (j, msg) in resubmits {
            if let Err(c) = send_to_shard(shared, j, &msg) {
                work.push((j, c));
            }
        }
    }
}

/// Per-shard reader: pumps frames into deliveries, heartbeat records
/// and stats snapshots until the connection dies (loss or teardown).
fn reader_loop(shared: Arc<ClusterShared>, shard: usize,
               mut stream: TcpStream) {
    loop {
        let payload = match read_frame(&mut stream) {
            Ok(p) => p,
            Err(WireError::Closed) => {
                shard_lost(&shared, shard, "connection closed");
                return;
            }
            Err(e) => {
                shard_lost(&shared, shard, &e.to_string());
                return;
            }
        };
        // a bad message in a good frame degrades that message only
        let msg = match Msg::decode(&payload) {
            Ok(m) => m,
            Err(e) => {
                warn_log!("cluster: shard {}: skipping bad message: \
                           {e:#}",
                          shared.addrs[shard]);
                continue;
            }
        };
        match msg {
            Msg::Response { id, images, .. } => {
                complete(&shared, id, Ok(images));
            }
            Msg::ErrorResp { id, err } => {
                complete(&shared, id, Err(err));
            }
            Msg::Pong { queue_depth, live_workers, ready_workers, .. } => {
                let mut st = shared.lock();
                st.health.pong(shard, queue_depth, live_workers,
                               ready_workers, Instant::now());
            }
            Msg::Stats { seq, stats } => {
                let mut st = shared.lock();
                // a snapshot racing the shard's death must not
                // resurrect the cleared entry (its slots re-count on
                // the survivors)
                if st.health.is_alive(shard) {
                    st.last_stats[shard] = Some(stats);
                    st.stats_seen[shard] =
                        st.stats_seen[shard].max(seq);
                }
                drop(st);
                shared.changed.notify_all();
            }
            other => {
                warn_log!("cluster: shard {}: skipping unexpected {} \
                           message",
                          shared.addrs[shard], other.kind());
            }
        }
    }
}

/// Heartbeat monitor: pings every alive shard each interval and
/// declares the ones past the timeout dead. The condvar wait lets
/// teardown interrupt a sleeping monitor immediately; spurious wakes
/// (delivery notifications share the condvar) are cheap because pings
/// are rate-limited to the heartbeat cadence.
fn monitor_loop(shared: Arc<ClusterShared>) {
    let heartbeat = shared.opts.health.heartbeat;
    let mut last_ping: Option<Instant> = None;
    loop {
        {
            let st = shared.lock();
            if st.closing {
                return;
            }
            let remaining = match last_ping {
                None => Duration::ZERO,
                Some(at) => heartbeat
                    .saturating_sub(at.elapsed()),
            };
            if !remaining.is_zero() {
                let (g, _) = shared
                    .changed
                    .wait_timeout(st, remaining)
                    .unwrap_or_else(|p| p.into_inner());
                if g.closing {
                    return;
                }
            }
        }
        if let Some(at) = last_ping {
            if at.elapsed() < heartbeat {
                continue; // woken by a notification, not the cadence
            }
        }
        last_ping = Some(Instant::now());
        let (seq, stats_seq, alive) = {
            let mut st = shared.lock();
            st.ping_seq += 1;
            // stats requests ride the heartbeat cadence so
            // `Cluster::stats()` is never more than one interval
            // stale; the shutdown sweep bumps the same counter, so
            // its wait still demands a strictly fresher snapshot
            st.stats_want += 1;
            (st.ping_seq, st.stats_want, st.health.alive_indices())
        };
        for i in alive {
            if let Err(c) =
                send_to_shard(&shared, i, &Msg::Ping { seq })
            {
                shard_lost(&shared, i,
                           &format!("heartbeat write failed: {c}"));
                continue;
            }
            let _ = send_to_shard(&shared, i,
                                  &Msg::StatsReq { seq: stats_seq });
        }
        let expired = {
            let st = shared.lock();
            st.health.expired(Instant::now())
        };
        for i in expired {
            let timeout = shared.opts.health.timeout;
            shard_lost(&shared, i,
                       &format!("heartbeat timeout (> {timeout:?})"));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::net::testutil::mock_node;
    use std::net::TcpListener;

    /// Fast heartbeats so pongs flow promptly, but a *generous*
    /// timeout: every death these tests exercise is detected via the
    /// severed connection (instant), and a tight timeout would let a
    /// loaded CI runner's scheduling stalls kill healthy mock nodes.
    fn fast_opts() -> ClusterOpts {
        ClusterOpts {
            health: HealthPolicy {
                heartbeat: Duration::from_millis(20),
                timeout: Duration::from_secs(5),
            },
            ..ClusterOpts::default()
        }
    }

    fn recv_ok(rx: &Receiver<GenResult>) -> GenResponse {
        rx.recv_timeout(Duration::from_secs(20))
            .expect("no hang")
            .expect("request must succeed")
    }

    #[test]
    fn two_nodes_serve_mixed_load_with_exact_routing() {
        // a small per-slot delay keeps work in flight while the submit
        // loop runs, so the in-flight placement estimate alternates
        // shards deterministically
        let (node_a, addr_a) =
            mock_node(vec![1, 2, 4], 3, Duration::from_millis(2));
        let (node_b, addr_b) =
            mock_node(vec![1, 2, 4], 3, Duration::from_millis(2));
        let cluster = Cluster::connect(
            &[addr_a.to_string(), addr_b.to_string()],
            fast_opts(),
        )
        .unwrap();
        let mut rxs = Vec::new();
        let mut total = 0usize;
        for i in 0..12usize {
            let n = 1 + i % 4;
            total += n;
            let class = (i % 7) as i32;
            let (_, rx) =
                cluster.submit(GenRequest { class, n }).unwrap();
            rxs.push((class, n, rx));
        }
        for (class, n, rx) in rxs {
            let resp = recv_ok(&rx);
            assert_eq!(resp.images.len(), n * 3);
            assert!(
                resp.images.iter().all(|&p| p == class as f32),
                "cross-shard pixel mixup for class {class}"
            );
        }
        let agg = cluster.shutdown();
        assert_eq!(agg.requests, 12);
        assert_eq!(agg.failed_requests, 0);
        assert_eq!(agg.nodes_lost, 0);
        // node-side compute counters aggregated over both shards
        assert_eq!(agg.images as usize, total);
        assert_eq!(agg.pending, 0);
        assert_eq!(agg.enqueued,
                   agg.dispatched + agg.purged + agg.pending);
        let st_a = node_a.shutdown();
        let st_b = node_b.shutdown();
        // placement spread work across both shards
        assert!(st_a.requests > 0 && st_b.requests > 0,
                "one shard starved: {} / {}", st_a.requests,
                st_b.requests);
        // cluster aggregate == sum of per-node shutdown stats for the
        // compute counters
        assert_eq!(st_a.images + st_b.images, agg.images);
        let mut summed = st_a.clone();
        summed.absorb(&st_b);
        assert_eq!(summed.enqueued,
                   summed.dispatched + summed.purged + summed.pending);
    }

    #[test]
    fn severed_node_requeues_inflight_to_survivor() {
        // slow backend holds work in flight long enough to sever under
        // load deterministically
        let (node_a, addr_a) =
            mock_node(vec![1, 2, 4], 2, Duration::from_millis(20));
        let (node_b, addr_b) =
            mock_node(vec![1, 2, 4], 2, Duration::from_millis(20));
        let cluster = Cluster::connect(
            &[addr_a.to_string(), addr_b.to_string()],
            fast_opts(),
        )
        .unwrap();
        let mut rxs = Vec::new();
        for i in 0..8usize {
            let class = (1 + i % 5) as i32;
            let (_, rx) =
                cluster.submit(GenRequest { class, n: 2 }).unwrap();
            rxs.push((class, rx));
        }
        // both shards now hold queued work (placement alternates on
        // the in-flight estimate); partition shard A mid-load
        std::thread::sleep(Duration::from_millis(5));
        node_a.sever_connections();
        for (class, rx) in rxs {
            let resp = recv_ok(&rx);
            assert_eq!(resp.images.len(), 2 * 2);
            assert!(resp.images.iter().all(|&p| p == class as f32));
        }
        let agg = cluster.shutdown();
        assert_eq!(agg.requests, 8);
        assert_eq!(agg.failed_requests, 0, "re-queue must be invisible");
        assert_eq!(agg.nodes_lost, 1);
        assert!(agg.requeued >= 1,
                "shard A held in-flight work when severed");
        // the dead shard is out of the aggregate; the survivor's
        // conservation identity still holds over the sum
        assert_eq!(agg.enqueued,
                   agg.dispatched + agg.purged + agg.pending);
        // per-node conservation also holds on the severed node, which
        // kept draining its dispatched work after the partition
        let st_a = node_a.shutdown();
        assert_eq!(st_a.enqueued,
                   st_a.dispatched + st_a.purged + st_a.pending);
        node_b.shutdown();
    }

    #[test]
    fn losing_every_node_fails_typed_never_hangs() {
        let (node, addr) =
            mock_node(vec![4], 2, Duration::from_millis(30));
        let cluster =
            Cluster::connect(&[addr.to_string()], fast_opts()).unwrap();
        let (_, rx) =
            cluster.submit(GenRequest { class: 1, n: 4 }).unwrap();
        std::thread::sleep(Duration::from_millis(5));
        node.sever_connections();
        match rx.recv_timeout(Duration::from_secs(20)).expect("no hang") {
            Err(ServeError::NodeLost { cause }) => {
                assert!(cause.contains(&addr.to_string()), "{cause}");
            }
            other => panic!("expected NodeLost, got {other:?}"),
        }
        // later submits fail fast with the recorded cause
        match cluster.submit(GenRequest { class: 0, n: 1 }) {
            Err(ServeError::NodeLost { .. }) => {}
            other => panic!("expected NodeLost reject, got {other:?}"),
        }
        let agg = cluster.shutdown();
        assert_eq!(agg.nodes_lost, 1);
        assert_eq!(agg.failed_requests, 1);
        node.shutdown();
    }

    #[test]
    fn silent_shard_is_timed_out_and_its_load_rehomed() {
        // a listener that accepts nothing: connects succeed (kernel
        // backlog) but no pong ever comes back
        let silent = TcpListener::bind("127.0.0.1:0").unwrap();
        let silent_addr = silent.local_addr().unwrap();
        let (node, addr) = mock_node(vec![1, 2, 4], 2, Duration::ZERO);
        // this test is the one that needs expiry itself to fire, so it
        // runs a shorter (but still stall-tolerant) timeout
        let cluster = Cluster::connect(
            &[silent_addr.to_string(), addr.to_string()],
            ClusterOpts {
                health: HealthPolicy {
                    heartbeat: Duration::from_millis(20),
                    timeout: Duration::from_millis(600),
                },
                ..ClusterOpts::default()
            },
        )
        .unwrap();
        // shard 0 (silent, reported depth 0) wins the first pick: its
        // requests must be re-homed once the heartbeat timeout fires
        let mut rxs = Vec::new();
        for i in 0..4usize {
            let class = (i % 3) as i32 + 1;
            let (_, rx) =
                cluster.submit(GenRequest { class, n: 1 }).unwrap();
            rxs.push((class, rx));
        }
        for (class, rx) in rxs {
            let resp = recv_ok(&rx);
            assert!(resp.images.iter().all(|&p| p == class as f32));
        }
        let agg = cluster.shutdown();
        assert_eq!(agg.requests, 4);
        assert_eq!(agg.failed_requests, 0);
        assert_eq!(agg.nodes_lost, 1, "the silent shard must time out");
        assert!(agg.requeued >= 1, "the silent shard got the first pick");
        node.shutdown();
        drop(silent);
    }

    #[test]
    fn cluster_backpressure_is_typed() {
        let (node, addr) =
            mock_node(vec![4], 2, Duration::from_millis(50));
        let cluster = Cluster::connect(
            &[addr.to_string()],
            ClusterOpts { max_queue: 4, ..fast_opts() },
        )
        .unwrap();
        let err =
            cluster.submit(GenRequest { class: 0, n: 5 }).unwrap_err();
        assert!(matches!(err,
                         ServeError::RequestTooLarge { n: 5, cap: 4 }));
        let (_, rx) =
            cluster.submit(GenRequest { class: 1, n: 3 }).unwrap();
        let err =
            cluster.submit(GenRequest { class: 2, n: 2 }).unwrap_err();
        assert!(matches!(err,
                         ServeError::QueueFull { queued: 3, cap: 4 }));
        recv_ok(&rx);
        cluster.shutdown();
        node.shutdown();
    }

    #[test]
    fn zero_image_request_completes_without_wire_traffic() {
        let (node, addr) = mock_node(vec![2], 2, Duration::ZERO);
        let cluster =
            Cluster::connect(&[addr.to_string()], fast_opts()).unwrap();
        let (id, rx) =
            cluster.submit(GenRequest { class: 1, n: 0 }).unwrap();
        let resp = recv_ok(&rx);
        assert_eq!(resp.id, id);
        assert!(resp.images.is_empty());
        cluster.shutdown();
        node.shutdown();
    }

    #[test]
    fn connect_to_nothing_errors() {
        // a bound-then-dropped listener gives a port that refuses
        let addr = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap()
        };
        let err = Cluster::connect(&[addr.to_string()], fast_opts())
            .unwrap_err();
        assert!(format!("{err:#}").contains("no shard node reachable"),
                "{err:#}");
        assert!(Cluster::connect(&[], fast_opts()).is_err());
    }
}
