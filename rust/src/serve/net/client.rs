//! Multiplexing node client: one reactor-backed connection, many
//! in-flight requests, per-request deadlines.
//!
//! The PR 4 client shape was implicit — callers owned a socket and a
//! reader thread per connection. [`NetClient`] replaces that with the
//! serve boundary's event-driven discipline: a single data-plane
//! connection driven by a [`Reactor`], request ids multiplexing any
//! number of in-flight submits over it, and an optional per-request
//! deadline that fails the *waiting* — never the connection — with a
//! typed [`ServeError::Deadline`]. A response landing after its
//! deadline fired is dropped silently (the request may well have
//! completed server-side; only the caller stopped waiting).
//!
//! What this deliberately is not: a [`Dispatch`](crate::serve::Dispatch)
//! implementation. The cluster is the `Dispatch`-shaped frontend with
//! placement, health and failover; `NetClient` is the thin per-node
//! SDK — one shard address, no liveness pings (the deadline is the
//! caller's hang protection), typed errors for everything else.

use std::collections::HashMap;
use std::net::TcpStream;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::obs::hist::LatencyHist;
use crate::obs::trace::TraceCtx;
use crate::serve::error::ServeError;
use crate::serve::net::proto::{Msg, Role, WIRE_BINARY};
use crate::serve::net::reactor::{
    Ctl, Driver, Handle, Reactor, ReactorOpts, Token,
};
use crate::serve::net::wire::{write_frame, WireError};
use crate::serve::router::{
    GenRequest, GenResponse, GenResult, ServerStats,
};
use crate::{debug_log, warn_log};

/// Client tuning knobs.
#[derive(Clone, Copy, Debug)]
pub struct NetClientOpts {
    /// Bound on the blocking connect + handshake.
    pub connect_timeout: Duration,
    /// Shutdown patience: how long `shutdown` waits for in-flight
    /// requests before failing them typed.
    pub drain: Duration,
}

impl Default for NetClientOpts {
    fn default() -> Self {
        NetClientOpts {
            connect_timeout: Duration::from_secs(5),
            drain: Duration::from_secs(30),
        }
    }
}

/// One outstanding request.
struct ClientPending {
    tx: Sender<GenResult>,
    n: usize,
    t0: Instant,
    /// Deadline budget in ms, when one was set (carried into the
    /// typed error so the caller sees what elapsed).
    deadline_ms: Option<u64>,
}

struct ClientState {
    open: bool,
    closing: bool,
    /// The one connection's token (`None` until `on_open`, and again
    /// after loss — there is no reconnect; callers make a new client).
    token: Option<Token>,
    pending: HashMap<u64, ClientPending>,
    requests: u64,
    failed_requests: u64,
    latency: LatencyHist,
    /// First terminal connection failure (colors later submits).
    lost: Option<String>,
}

struct ClientShared {
    addr: String,
    state: Mutex<ClientState>,
    /// Signaled on delivery, connection open/loss and teardown.
    changed: Condvar,
    reactor: OnceLock<Handle<()>>,
}

impl ClientShared {
    fn lock(&self) -> std::sync::MutexGuard<'_, ClientState> {
        crate::util::lock(&self.state)
    }

    /// Fail every pending request with `err()`; shared by loss,
    /// shutdown stragglers and drop.
    fn fail_all(&self, err: impl Fn() -> ServeError) {
        let mut st = self.lock();
        let ids: Vec<u64> = st.pending.keys().copied().collect();
        for id in ids {
            if let Some(p) = st.pending.remove(&id) {
                st.failed_requests += 1;
                let _ = p.tx.send(Err(err()));
            }
        }
        drop(st);
        self.changed.notify_all();
    }
}

/// Handle to one shard node over one multiplexed connection. `Sync`:
/// any number of threads submit through a shared reference.
pub struct NetClient {
    shared: Arc<ClientShared>,
    next_id: AtomicU64,
    reactor: Option<Reactor>,
    opts: NetClientOpts,
    t_start: Instant,
}

/// The client's [`Driver`]: route responses to their waiters, fire
/// deadlines, fail everything typed on loss. Timer keys are request
/// ids (unique per client, so a fired key whose request already
/// resolved is inert).
struct ClientDriver {
    shared: Arc<ClientShared>,
}

impl Driver for ClientDriver {
    type Tag = ();

    fn accept_tag(&mut self, _listener: Token,
                  _peer: std::net::SocketAddr) {
        // zero listeners: nothing accepts
    }

    fn on_open(&mut self, _ctl: &mut Ctl<'_>, token: Token, _tag: ()) {
        let mut st = self.shared.lock();
        st.token = Some(token);
        drop(st);
        self.shared.changed.notify_all();
    }

    fn on_message(&mut self, _ctl: &mut Ctl<'_>, _token: Token,
                  payload: Vec<u8>) {
        let msg = match Msg::decode(&payload) {
            Ok(m) => m,
            Err(e) => {
                warn_log!("client: {}: skipping bad message: {e:#}",
                          self.shared.addr);
                return;
            }
        };
        match msg {
            Msg::Response { id, images, .. } => {
                complete(&self.shared, id, Ok(images));
            }
            Msg::ErrorResp { id, err } => {
                complete(&self.shared, id, Err(err));
            }
            Msg::HelloAck { wire } => {
                debug_log!("client: {}: wire level {wire} acknowledged",
                           self.shared.addr);
            }
            Msg::Reject { err } => {
                // connection-level refusal: remember the cause (the
                // close that follows fails the in-flight requests)
                let mut st = self.shared.lock();
                st.lost
                    .get_or_insert(format!("node rejected the \
                                            connection: {err}"));
            }
            other => {
                debug_log!("client: {}: ignoring {} message",
                           self.shared.addr, other.kind());
            }
        }
    }

    fn on_close(&mut self, _ctl: &mut Ctl<'_>, token: Token,
                cause: WireError) {
        let closing;
        let cause = {
            let mut st = self.shared.lock();
            if st.token == Some(token) {
                st.token = None;
            }
            closing = st.closing;
            st.lost
                .get_or_insert_with(|| match &cause {
                    WireError::Closed => "connection closed".into(),
                    e => e.to_string(),
                })
                .clone()
        };
        if !closing {
            warn_log!("client: {}: connection lost: {cause}",
                      self.shared.addr);
        }
        self.shared.fail_all(|| ServeError::NodeLost {
            cause: format!("{}: {cause}", self.shared.addr),
        });
    }

    fn on_timer(&mut self, _ctl: &mut Ctl<'_>, key: u64) {
        // a deadline fired: if the request still waits, stop the wait
        // (the node may still answer — that response is then dropped)
        let mut st = self.shared.lock();
        let Some(p) = st.pending.remove(&key) else { return };
        st.failed_requests += 1;
        let after_ms = p.deadline_ms.unwrap_or(0);
        let _ = p.tx.send(Err(ServeError::Deadline { after_ms }));
        drop(st);
        self.shared.changed.notify_all();
    }
}

/// Deliver a terminal outcome for request `id`; a request whose
/// deadline already fired is gone from `pending` — late response
/// dropped, as documented.
fn complete(shared: &ClientShared, id: u64,
            outcome: std::result::Result<Vec<f32>, ServeError>) {
    let mut st = shared.lock();
    let Some(p) = st.pending.remove(&id) else {
        debug_log!("client: late/duplicate answer for request {id} \
                    dropped");
        return;
    };
    let latency_s = p.t0.elapsed().as_secs_f64();
    match outcome {
        Ok(images) => {
            st.latency.record(latency_s);
            let _ = p.tx.send(Ok(GenResponse { id, images, latency_s }));
        }
        Err(err) => {
            st.failed_requests += 1;
            let _ = p.tx.send(Err(err));
        }
    }
    drop(st);
    shared.changed.notify_all();
}

impl NetClient {
    /// Connect to a shard node's data plane. The blocking dial and
    /// `Hello` handshake happen here, bounded by
    /// [`NetClientOpts::connect_timeout`]; everything after is
    /// event-driven.
    pub fn connect(addr: &str, opts: NetClientOpts) -> Result<NetClient> {
        use std::net::ToSocketAddrs;
        let mut found = None;
        let mut last_err = None;
        for target in addr
            .to_socket_addrs()
            .with_context(|| format!("resolving {addr}"))?
        {
            match TcpStream::connect_timeout(&target,
                                             opts.connect_timeout) {
                Ok(s) => {
                    found = Some(s);
                    break;
                }
                Err(e) => last_err = Some(e),
            }
        }
        let Some(mut stream) = found else {
            let e = last_err.map_or_else(
                || "no resolvable address".to_string(),
                |e| e.to_string(),
            );
            anyhow::bail!("connecting to node {addr}: {e}");
        };
        let _ = stream.set_nodelay(true);
        let _ = stream.set_write_timeout(Some(opts.connect_timeout));
        let hello = Msg::Hello { role: Role::Data,
                                 max_wire: WIRE_BINARY };
        write_frame(&mut stream, &hello.encode())
            .map_err(|e| anyhow::anyhow!("{addr}: handshake: {e}"))?;
        let shared = Arc::new(ClientShared {
            addr: addr.to_string(),
            state: Mutex::new(ClientState {
                open: true,
                closing: false,
                token: None,
                pending: HashMap::new(),
                requests: 0,
                failed_requests: 0,
                latency: LatencyHist::new(),
                lost: None,
            }),
            changed: Condvar::new(),
            reactor: OnceLock::new(),
        });
        let driver = ClientDriver { shared: Arc::clone(&shared) };
        let (reactor, handle, _) =
            Reactor::spawn(driver, Vec::new(), ReactorOpts::default())
                .context("spawning client reactor")?;
        let _ = shared.reactor.set(handle.clone());
        if !handle.register(stream, ()) {
            anyhow::bail!("client reactor stopped during connect");
        }
        // wait (bounded) for the token: submits route through it
        {
            let deadline = Instant::now() + opts.connect_timeout;
            let mut st = shared.lock();
            while st.token.is_none() {
                let now = Instant::now();
                if now >= deadline {
                    anyhow::bail!(
                        "{addr}: reactor registration timed out");
                }
                let (g, _) = shared
                    .changed
                    .wait_timeout(st, deadline - now)
                    .unwrap_or_else(|p| p.into_inner());
                st = g;
            }
        }
        Ok(NetClient {
            shared,
            next_id: AtomicU64::new(0),
            reactor: Some(reactor),
            opts,
            t_start: Instant::now(),
        })
    }

    /// Submit with no deadline: the response channel resolves when the
    /// node answers or the connection dies (typed, never a hang).
    pub fn submit(&self, req: GenRequest)
                  -> std::result::Result<(u64, Receiver<GenResult>),
                                         ServeError> {
        self.submit_inner(req, None)
    }

    /// Submit with a per-request deadline: if no response arrives in
    /// `deadline`, the waiter gets [`ServeError::Deadline`] and a late
    /// response is dropped. The connection is unaffected — other
    /// in-flight requests keep waiting on their own terms.
    pub fn submit_with_deadline(&self, req: GenRequest,
                                deadline: Duration)
                                -> std::result::Result<
                                    (u64, Receiver<GenResult>),
                                    ServeError> {
        self.submit_inner(req, Some(deadline))
    }

    fn submit_inner(&self, req: GenRequest, deadline: Option<Duration>)
                    -> std::result::Result<(u64, Receiver<GenResult>),
                                           ServeError> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = channel();
        let token = {
            let mut st = self.shared.lock();
            if !st.open {
                return Err(ServeError::ShuttingDown);
            }
            let Some(token) = st.token else {
                return Err(ServeError::NodeLost {
                    cause: format!(
                        "{}: {}",
                        self.shared.addr,
                        st.lost
                            .as_deref()
                            .unwrap_or("connection closed")
                    ),
                });
            };
            st.requests += 1;
            if req.n == 0 {
                // nothing to compute: complete immediately, no wire
                let _ = tx.send(Ok(GenResponse {
                    id,
                    images: Vec::new(),
                    latency_s: 0.0,
                }));
                return Ok((id, rx));
            }
            st.pending.insert(id, ClientPending {
                tx,
                n: req.n,
                t0: Instant::now(),
                deadline_ms: deadline
                    .map(|d| d.as_millis().min(u64::MAX as u128) as u64),
            });
            token
        };
        let Some(handle) = self.shared.reactor.get() else {
            // connect() sets this before handing the client out; a
            // missing reactor is a broken handle, not a broken process
            let mut st = self.shared.lock();
            st.pending.remove(&id);
            st.failed_requests += 1;
            return Err(ServeError::NodeLost {
                cause: format!("{}: client reactor not initialized",
                               self.shared.addr),
            });
        };
        let msg = Msg::Submit { id, class: req.class, n: req.n,
                                trace: TraceCtx::NONE };
        if !handle.send(token, msg.encode()) {
            // reactor gone: fail this one typed, right now
            let mut st = self.shared.lock();
            if let Some(p) = st.pending.remove(&id) {
                st.failed_requests += 1;
                let _ = p.tx.send(Err(ServeError::NodeLost {
                    cause: format!("{}: client reactor stopped",
                                   self.shared.addr),
                }));
            }
            return Ok((id, rx));
        }
        if let Some(d) = deadline {
            handle.timer(Instant::now() + d, id);
        }
        Ok((id, rx))
    }

    /// Image slots submitted but not yet resolved.
    pub fn queue_depth(&self) -> usize {
        self.shared.lock().pending.values().map(|p| p.n).sum()
    }

    /// Client-side stats overlay: request/failure counts and the
    /// end-to-end latency histogram. (Node-side counters live on the
    /// node; ask it, or the cluster, for those.)
    pub fn stats(&self) -> ServerStats {
        let st = self.shared.lock();
        let mut s = ServerStats {
            requests: st.requests,
            failed_requests: st.failed_requests,
            wall_s: self.t_start.elapsed().as_secs_f64(),
            ..ServerStats::default()
        };
        s.latency = st.latency.clone();
        s.latency_p50_s = s.latency.quantile(0.50);
        s.latency_p95_s = s.latency.quantile(0.95);
        s
    }

    /// Stop accepting, wait (bounded by [`NetClientOpts::drain`]) for
    /// in-flight requests, fail stragglers typed, and return the
    /// client-side stats.
    pub fn shutdown(mut self) -> ServerStats {
        {
            let mut st = self.shared.lock();
            st.open = false;
        }
        let deadline = Instant::now() + self.opts.drain;
        {
            let mut st = self.shared.lock();
            while !st.pending.is_empty() && st.token.is_some() {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                let (g, _) = self
                    .shared
                    .changed
                    .wait_timeout(st, deadline - now)
                    .unwrap_or_else(|p| p.into_inner());
                st = g;
            }
        }
        self.shared.fail_all(|| ServeError::ShuttingDown);
        self.teardown();
        self.stats()
    }

    fn teardown(&mut self) {
        {
            let mut st = self.shared.lock();
            st.closing = true;
        }
        self.shared.changed.notify_all();
        if let Some(h) = self.shared.reactor.get() {
            h.stop();
        }
        if let Some(r) = self.reactor.take() {
            r.join();
        }
    }
}

impl Drop for NetClient {
    /// A client dropped without `shutdown` still fails its in-flight
    /// requests typed and joins the reactor — never a stranded waiter.
    fn drop(&mut self) {
        {
            let mut st = self.shared.lock();
            st.open = false;
        }
        self.shared.fail_all(|| ServeError::ShuttingDown);
        self.teardown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::net::node::NodeOpts;
    use crate::serve::net::testutil::{mock_node, mock_node_opts};

    fn recv_ok(rx: &Receiver<GenResult>) -> GenResponse {
        rx.recv_timeout(Duration::from_secs(20))
            .expect("no hang")
            .expect("request must succeed")
    }

    #[test]
    fn client_multiplexes_many_inflight_requests_on_one_socket() {
        // reactor node + reactor client: binary responses end to end,
        // ten requests in flight over the one connection
        let nopts = NodeOpts { reactor: true, ..NodeOpts::default() };
        let (node, addr) = mock_node_opts(
            vec![1, 2, 4], 3, Duration::from_millis(5), nopts);
        let client = NetClient::connect(&addr.to_string(),
                                        NetClientOpts::default())
            .unwrap();
        let mut rxs = Vec::new();
        for i in 0..10usize {
            let class = (i % 4) as i32;
            let n = 1 + i % 3;
            let (_, rx) =
                client.submit(GenRequest { class, n }).unwrap();
            rxs.push((class, n, rx));
        }
        assert!(client.queue_depth() > 0,
                "submits must be in flight concurrently");
        for (class, n, rx) in rxs {
            let resp = recv_ok(&rx);
            assert_eq!(resp.images.len(), n * 3);
            assert!(resp.images.iter().all(|&p| p == class as f32),
                    "wrong pixels for class {class}");
        }
        let cs = client.shutdown();
        assert_eq!(cs.requests, 10);
        assert_eq!(cs.failed_requests, 0);
        let st = node.shutdown();
        assert_eq!(st.requests, 10);
        assert_eq!(st.enqueued,
                   st.dispatched + st.purged + st.pending);
    }

    #[test]
    fn client_deadline_fails_typed_then_connection_keeps_serving() {
        // slow backend: the deadline fires first; the connection (and
        // a later, patient request) is unaffected
        let (node, addr) =
            mock_node(vec![1, 2], 2, Duration::from_millis(150));
        let client = NetClient::connect(&addr.to_string(),
                                        NetClientOpts::default())
            .unwrap();
        let (_, rx) = client
            .submit_with_deadline(GenRequest { class: 1, n: 2 },
                                  Duration::from_millis(30))
            .unwrap();
        match rx.recv_timeout(Duration::from_secs(10)).expect("no hang") {
            Err(ServeError::Deadline { after_ms: 30 }) => {}
            other => panic!("expected Deadline, got {other:?}"),
        }
        // the late response for the first request is dropped silently;
        // a patient second request still completes on the same socket
        let (_, rx) = client
            .submit_with_deadline(GenRequest { class: 2, n: 1 },
                                  Duration::from_secs(30))
            .unwrap();
        let resp = recv_ok(&rx);
        assert!(resp.images.iter().all(|&p| p == 2.0));
        let cs = client.shutdown();
        assert_eq!(cs.requests, 2);
        assert_eq!(cs.failed_requests, 1);
        node.shutdown();
    }

    #[test]
    fn client_connection_loss_fails_pending_typed() {
        let (node, addr) =
            mock_node(vec![2], 2, Duration::from_millis(100));
        let client = NetClient::connect(&addr.to_string(),
                                        NetClientOpts::default())
            .unwrap();
        let (_, rx) =
            client.submit(GenRequest { class: 1, n: 2 }).unwrap();
        std::thread::sleep(Duration::from_millis(10));
        node.sever_connections();
        match rx.recv_timeout(Duration::from_secs(10)).expect("no hang") {
            Err(ServeError::NodeLost { cause }) => {
                assert!(cause.contains(&addr.to_string()), "{cause}");
            }
            other => panic!("expected NodeLost, got {other:?}"),
        }
        // later submits fail fast with the recorded cause
        match client.submit(GenRequest { class: 0, n: 1 }) {
            Err(ServeError::NodeLost { .. }) => {}
            other => panic!("expected NodeLost reject, got {other:?}"),
        }
        client.shutdown();
        node.shutdown();
    }

    #[test]
    fn client_zero_image_request_completes_without_wire_traffic() {
        let (node, addr) = mock_node(vec![2], 2, Duration::ZERO);
        let client = NetClient::connect(&addr.to_string(),
                                        NetClientOpts::default())
            .unwrap();
        let (id, rx) =
            client.submit(GenRequest { class: 1, n: 0 }).unwrap();
        let resp = recv_ok(&rx);
        assert_eq!(resp.id, id);
        assert!(resp.images.is_empty());
        client.shutdown();
        node.shutdown();
    }

    #[test]
    fn dropped_client_fails_pending_typed() {
        let (node, addr) =
            mock_node(vec![2], 2, Duration::from_millis(100));
        let client = NetClient::connect(&addr.to_string(),
                                        NetClientOpts::default())
            .unwrap();
        let (_, rx) =
            client.submit(GenRequest { class: 1, n: 2 }).unwrap();
        drop(client);
        match rx.recv_timeout(Duration::from_secs(10)).expect("no hang") {
            Err(ServeError::ShuttingDown) => {}
            other => panic!("expected ShuttingDown, got {other:?}"),
        }
        node.shutdown();
    }
}
