//! Batched generation service: request queue + dynamic batcher + a
//! worker loop that drives the sampler.
//!
//! The PJRT runtime is not `Send` (executables are `Rc`), so the server
//! constructs runtime + sampler *inside* its worker thread and talks to
//! clients over channels. The [`batcher`] itself is a pure data
//! structure (unit- and property-tested without a runtime): it splits
//! requests into image slots, fills fixed-size artifact batches FIFO,
//! and never starves a request.

pub mod batcher;
pub mod server;

pub use batcher::{Batcher, Slot};
pub use server::{GenRequest, GenResponse, GenServer, ServerStats};
