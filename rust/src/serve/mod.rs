//! Sharded generation service: request queue + dynamic batcher + a
//! router that fans batches out to N sampler-owning worker threads.
//!
//! # Threading model
//!
//! The PJRT runtime is not `Send` (executables are `Rc`), so nothing
//! runtime-shaped ever crosses a thread boundary. Instead:
//!
//! * **Clients** hold a [`GenServer`] (or raw [`router::Router`])
//!   handle, which is `Sync` — any number of client threads submit
//!   through one shared reference. `submit` assigns ids from an atomic
//!   counter and returns a per-request response channel; it *returns*
//!   typed [`ServeError`]s (shutdown, backpressure, dead service)
//!   rather than panicking.
//! * **Workers** are long-lived threads that each build their own
//!   pipeline + sampler *inside* the thread ([`router::WorkerBody`]),
//!   then loop: lock the shared state, pop the oldest batch from the
//!   FIFO [`Batcher`], unlock, generate, re-lock and route results back
//!   to the waiting clients. Whichever worker is idle takes the next
//!   batch (work-stealing), so one slow shard never stalls the queue.
//! * **Calibration** runs once, not per worker: the first pipeline to
//!   come up resolves the `QuantConfig` — loading it from the
//!   persistent calibration cache when warm, calibrating (and
//!   persisting) otherwise — and publishes it; the other workers clone
//!   the shared qparams (see [`server`] and
//!   [`crate::coordinator::cache`]).
//!
//! Worker failures propagate as [`ServeError`]s on the affected
//! clients' channels — no hangs, no process panics — and the service
//! keeps serving on the surviving workers. The [`batcher`] itself is a
//! pure data structure (unit- and property-tested without a runtime):
//! it splits requests into image slots, fills fixed-size artifact
//! batches FIFO, and never starves a request.

pub mod batcher;
pub mod error;
pub mod router;
pub mod server;

pub use batcher::{Batcher, Slot};
pub use error::ServeError;
pub use router::{
    GenBackend, GenRequest, GenResponse, GenResult, Router, RouterOpts,
    ServerStats, WorkerBody, WorkerHandle, WorkerStats,
};
pub use server::GenServer;
