//! Sharded generation service: request queue + dynamic batcher + a
//! deadline-aware batch policy + a router that fans rung-sized batches
//! out to N sampler-owning worker threads — locally, or across hosts
//! through the [`net`] layer.
//!
//! # Architecture (client → cluster → wire → node → router)
//!
//! ```text
//! clients ──submit──▶ Cluster ── data plane ──▶ NodeServer ─┐  (remote,
//!    │                  │ (least-loaded shard,   reactor or │ serve/net)
//!    │                  │  binary image frames,  per-conn   │
//!    │                  │  re-queue on node loss) handlers  │
//!    │                  │  Submit{trace} out at ≥WIRE_TRACE,│
//!    │                  │  Response{spans} home, re-based   │
//!    │                  │  into the Dispatch hop's window   │
//!    │                  └─ control plane (Hello{role}) ──▶──┤
//!    │                     ping/pong + pushed stats deltas; │
//!    │                     health Alive→Suspect→Dead→       │
//!    │                     Probation→Alive (re-admission)   ▼
//!    │   both ends event-driven at --reactor: one poll(2)   │
//!    │   thread per process owns every connection, timer    │
//!    │   wheel drives heartbeats and request deadlines;     │
//!    │   a reactor node can also serve GET /metrics         │
//!    │   (--metrics-addr) as one more connection class ◀────┘
//!    └──────────────── in-process (GenServer) ──────▶ Router
//!                                                          │
//!                     Batcher (FIFO slots, arrival times, counters)
//!                        │
//!            BatchPolicy.plan(ladder, pending, oldest_wait, draining)
//!                        │            │
//!                 Dispatch{rung,take} Wait{remaining}
//!                        │            └─ park on condvar ≤ remaining
//!                        ▼
//!        worker: pad take→rung, generate on the rung's executable,
//!                deliver (per-rung stats) or fail (typed errors)
//!
//! observability (crate::obs), riding the same paths when --trace is
//! on: Request ─┬─ Queue / Linger            (batcher wait)
//!              ├─ Dispatch{shard}           (cluster hop, wire time)
//!              │    └─ Request (node side, spans shipped home)
//!              ├─ RungPick → Generate ─ StepsFull | StepsReuse
//!              └─ Encode                    (response serialization)
//! spans land in a lock-free ring (trace::snapshot / --trace-json);
//! latency lives in mergeable log-linear histograms (obs::hist) that
//! flow through StatsDelta pushes, stats folds and /metrics scrapes.
//! ```
//!
//! Both entry points implement the [`Dispatch`] trait — submit /
//! queue-depth / live stats / consuming shutdown — so everything above
//! the router (CLI, demo, benches, shard nodes) drives a
//! `Box<dyn Dispatch>` and cannot tell local from clustered serving.
//!
//! * **[`Batcher`]** is a pure FIFO of per-image slots. It knows
//!   nothing about batch sizes; it tracks arrival times (for the
//!   linger deadline) and conservation counters
//!   (`enqueued == dispatched + purged + pending`).
//! * **[`policy`]** owns the *batch ladder*: the sampling artifacts are
//!   lowered at several batch dims (`Manifest::batches.sample`), and
//!   [`BatchPolicy`] decides per dispatch whether to run now — on the
//!   smallest rung covering the queue, never padding when an exact
//!   rung fits — or linger up to a deadline for more fill. A one-rung
//!   ladder with zero linger reproduces the classic fixed-batch
//!   behavior exactly.
//! * **[`router`]** runs the worker threads. Every idle worker locks
//!   the shared state, consults the policy, and either pops its batch
//!   (work-stealing: whichever worker is free takes the oldest work)
//!   or parks on the condvar with the linger deadline as timeout.
//!   Per-rung batch/padding/latency accounting lands in
//!   [`WorkerStats`]/[`ServerStats`].
//! * **Step reuse** happens one layer below the router, inside each
//!   worker's [`crate::sampler::Sampler`]: a timestep-aware reuse plan
//!   ([`crate::sampler::reuse::ReusePolicy`], threshold `--reuse-delta`,
//!   δ=0 ⇒ byte-identical to the dense trajectory) serves low-drift
//!   steps from the group's cached ε̂ with closed-form coefficient
//!   fusion instead of running the transformer. The backend reports
//!   lifetime totals through [`GenBackend::reuse_counters`]; the
//!   router folds them into [`WorkerStats`]/[`ServerStats`] as
//!   `reuse_hits` / `steps_skipped` / `uploads_saved`, and the net
//!   layer carries them in stats deltas and cluster folds like every
//!   other counter.
//!
//! # Threading model
//!
//! The PJRT runtime is not `Send` (executables are `Rc`), so nothing
//! runtime-shaped ever crosses a thread boundary. Instead:
//!
//! * **Clients** hold a [`GenServer`] (or raw [`router::Router`])
//!   handle, which is `Sync` — any number of client threads submit
//!   through one shared reference. `submit` assigns ids from an atomic
//!   counter and returns a per-request response channel; it *returns*
//!   typed [`ServeError`]s (shutdown, backpressure, dead service)
//!   rather than panicking.
//! * **Workers** are long-lived threads that each build their own
//!   pipeline + sampler *ladder* inside the thread
//!   ([`router::WorkerBody`]) — one sampler per served rung, all
//!   sharing a single resident upload of the quantized weights — then
//!   loop on the policy-driven dispatch above.
//! * **Calibration** runs once, not per worker: the first pipeline to
//!   come up resolves the `QuantConfig` — loading it from the
//!   persistent calibration cache when warm, calibrating (and
//!   persisting) otherwise — and publishes it; the other workers clone
//!   the shared qparams (see [`server`] and
//!   [`crate::coordinator::cache`]).
//!
//! # Failure propagation
//!
//! Worker failures propagate as [`ServeError`]s on the affected
//! clients' channels — no hangs, no process panics — and the service
//! keeps serving on the surviving workers. An invalid backend ladder
//! fails the worker at init (before it marks ready); a worker dying
//! mid-rung fails exactly the requests with slots in that batch and
//! purges their queued remainder. When the last worker exits, every
//! queued client receives a typed `AllWorkersDead` with the first
//! recorded cause. The [`batcher`] and [`policy`] are pure data
//! structures (unit- and property-tested without a runtime).
//!
//! Across hosts the same discipline holds one level up: a lost shard
//! node has its in-flight requests re-queued onto surviving shards by
//! the [`net::Cluster`], and only when no shard remains do clients see
//! a typed [`ServeError::NodeLost`] — zero hangs either way. Liveness
//! itself is isolated from the data plane (each shard gets a dedicated
//! control connection, so a node busy streaming multi-MiB responses is
//! never mistaken for a dead one), and death is recoverable: dead
//! shards are re-dialed, probed, and re-admitted into placement with a
//! ramp-up weight (see [`net::health`]).
//!
//! # Concurrency invariants (machine-checked by `tq-dit lint`)
//!
//! The serve stack's locking discipline is enforced by the crate's own
//! static analysis ([`crate::analysis`]), which runs in CI and in a
//! dogfood unit test — the invariants below are *checked*, not
//! aspirational:
//!
//! * **No blocking under a lock** (`lock-across-blocking`): no mutex
//!   guard may be held across socket/frame I/O, channel `recv`,
//!   `sleep` or `join` — *directly or through any call chain*: the
//!   lint builds a whole-program call graph and infers transitive
//!   blocking, so hiding a `write_all` two helpers deep still fires
//!   (the finding prints the chain). State updates happen under the
//!   lock; wire writes happen after it is released (the lost-node
//!   path re-queues on failure). The deliberate exceptions carry
//!   `// tq-lint: allow(...)` pragmas with their justification: the
//!   thread-pool worker whose receiver mutex *is* the work queue, the
//!   bounded single-frame writes in [`net::send_message`] /
//!   `cluster::send_control` where the chunk protocol releases the
//!   frame lock between chunks, and `cluster::send_data`, a
//!   mode-dispatch shim declared `allow(transitive-blocking)` because
//!   its reactor-mode path never blocks. CI ratchets the pragma count
//!   against `rust/lint_pragmas.baseline`, so the exception list can
//!   shrink but never silently grow.
//! * **Lock order** (`lock-order`): nested acquisitions must ascend
//!   the declared registry — `state` (0) → `readers` (1) → `bulk` (2)
//!   → `data`/`ctrl`/`stream`/`half` (3) → `record` (4) — and no
//!   unregistered mutex may be taken while one is held. Condvar
//!   `wait`s consume their guard and are exempt by construction.
//! * **No panics on the request path** (`no-panic-paths`):
//!   `.unwrap()`/`.expect()`/`panic!`-family are banned in production
//!   `serve/`, `runtime/`, `sampler/` and `obs/` code — failures
//!   surface as typed
//!   [`ServeError`]s or logged degradation. On `serve/net` decode
//!   paths, slice-indexing peer-controlled bytes is banned too (the
//!   total `wire::be_*` readers exist for exactly this). Tests are
//!   exempt; provably-infallible sites carry a pragma with a reason.
//! * **Protocol matches stay loud** (`protocol-exhaustiveness`): no
//!   silent `_ => {}` over `Msg`/`WireError`/`ShardState`/`Role`/
//!   `Health` in `serve/net` — a new wire variant must force a
//!   decision, not vanish.
//! * **Reactor callbacks never block** (`reactor-discipline`): `on_*`
//!   handlers and `Ctl`-taking fns outside `reactor.rs` must hand
//!   blocking work to the pool — again transitively, through the
//!   inferred call graph; one stalled callback would freeze every
//!   connection on the loop.
//! * **One way to lock** (`non-poisoning-lock`): every
//!   `std::sync::Mutex` is taken through [`crate::util::lock`], which
//!   recovers from poisoning instead of cascading `PoisonError`s.
//! * **Stats are plumbed end-to-end** (`stats-plumbing`): every field
//!   of [`ServerStats`], `WorkerStats`, `RungStats` and
//!   [`crate::sampler::SampleStats`], and every [`net::proto`] `Msg`
//!   variant, must be mentioned in its serde encode *and* decode, in
//!   `ServerStats::absorb`, and in the cluster's `stats_fold` — a new
//!   counter that is counted but never aggregated (or folded but never
//!   shipped) is a lint finding at the field's definition. Fields that
//!   are *deliberately* not folded (gauges and breakdowns where the
//!   latest node delta wins, e.g. `queue_depth_max`) are declared in
//!   the `STATS_EXEMPT` registry next to the rule, each with a reason
//!   — the exemption is in the diff, not in a reviewer's memory.

pub mod batcher;
pub mod dispatch;
pub mod error;
pub mod net;
pub mod policy;
pub mod router;
pub mod server;

pub use batcher::{Batcher, BatcherCounters, Slot};
pub use dispatch::Dispatch;
pub use error::ServeError;
pub use net::{
    Cluster, ClusterOpts, HealthPolicy, NetClient, NetClientOpts,
    NodeOpts, NodeServer,
};
pub use policy::{BatchPlan, BatchPolicy, Ladder};
pub use router::{
    GenBackend, GenRequest, GenResponse, GenResult, Router, RouterOpts,
    RungStats, ServerStats, WorkerBody, WorkerHandle, WorkerStats,
};
pub use server::GenServer;
