//! Dynamic batcher: expands generation requests into per-image slots
//! and hands them out FIFO. The batcher is a pure queue — *which* rung
//! of the lowered batch ladder a pop targets, and whether to linger
//! for more fill first, is decided by [`crate::serve::policy`]; the
//! batcher only tracks slots, their arrival times (for the linger
//! deadline), and conservation counters.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use crate::obs::trace::TraceCtx;

/// One image's worth of pending work.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Slot {
    /// Originating request.
    pub req_id: u64,
    /// Class label to condition on.
    pub class: i32,
    /// Index of this image within its request.
    pub index: usize,
    /// Trace context of the originating request ([`TraceCtx::NONE`]
    /// when untraced) — rides the slot so the dispatching worker can
    /// parent its batch spans without re-locking request state.
    pub trace: TraceCtx,
}

/// Lifetime slot-flow counters. Conservation invariant:
/// `enqueued == dispatched + purged + pending` at every quiescent
/// point (pending being the live queue length).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct BatcherCounters {
    pub enqueued: u64,
    pub dispatched: u64,
    /// Slots removed without dispatch (`drop_request` / `clear`).
    pub purged: u64,
}

/// FIFO slot queue with arrival-time tracking.
#[derive(Debug, Default)]
pub struct Batcher {
    queue: VecDeque<(Slot, Instant)>,
    counters: BatcherCounters,
}

impl Batcher {
    pub fn new() -> Batcher {
        Batcher::default()
    }

    /// Expand a request for `n` images of `class` into slots.
    pub fn push_request(&mut self, req_id: u64, class: i32, n: usize) {
        self.push_request_at(req_id, class, n, Instant::now());
    }

    /// [`Self::push_request`] carrying the request's trace context on
    /// every slot (the router's submit path).
    pub fn push_request_traced(&mut self, req_id: u64, class: i32,
                               n: usize, trace: TraceCtx) {
        let at = Instant::now();
        for index in 0..n {
            self.queue
                .push_back((Slot { req_id, class, index, trace }, at));
            self.counters.enqueued += 1;
        }
    }

    /// [`Self::push_request`] with an explicit arrival instant (tests
    /// drive the linger deadline with a mock clock, no sleeps).
    pub fn push_request_at(&mut self, req_id: u64, class: i32, n: usize,
                           at: Instant) {
        let trace = TraceCtx::NONE;
        for index in 0..n {
            self.queue
                .push_back((Slot { req_id, class, index, trace }, at));
            self.counters.enqueued += 1;
        }
    }

    /// Pending image slots.
    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// How long the oldest queued slot has been waiting as of `now`
    /// (`None` when idle; saturates to zero if `now` races behind the
    /// arrival stamp).
    pub fn oldest_wait(&self, now: Instant) -> Option<Duration> {
        self.queue
            .front()
            .map(|(_, at)| now.saturating_duration_since(*at))
    }

    /// Take up to `n` slots FIFO (the policy's `take`). Returns an
    /// empty vec when idle.
    pub fn take(&mut self, n: usize) -> Vec<Slot> {
        let take = self.queue.len().min(n);
        let batch: Vec<Slot> =
            self.queue.drain(..take).map(|(s, _)| s).collect();
        self.counters.dispatched += batch.len() as u64;
        batch
    }

    /// Lifetime flow counters (see [`BatcherCounters`]).
    pub fn counters(&self) -> BatcherCounters {
        self.counters
    }

    /// Remove every queued slot belonging to `req_id` (the request
    /// failed elsewhere); returns how many slots were purged. Purged
    /// slots count toward `counters().purged`, keeping conservation.
    pub fn drop_request(&mut self, req_id: u64) -> usize {
        let before = self.queue.len();
        self.queue.retain(|(s, _)| s.req_id != req_id);
        let purged = before - self.queue.len();
        self.counters.purged += purged as u64;
        purged
    }

    /// Drop all queued slots (service aborting); returns the count.
    pub fn clear(&mut self) -> usize {
        let n = self.queue.len();
        self.queue.clear();
        self.counters.purged += n as u64;
        n
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::{check, Gen};

    #[test]
    fn fifo_order_within_and_across_requests() {
        let mut b = Batcher::new();
        b.push_request(1, 3, 2);
        b.push_request(2, 5, 1);
        let batch = b.take(8);
        let none = TraceCtx::NONE;
        assert_eq!(
            batch,
            vec![
                Slot { req_id: 1, class: 3, index: 0, trace: none },
                Slot { req_id: 1, class: 3, index: 1, trace: none },
                Slot { req_id: 2, class: 5, index: 0, trace: none },
            ]
        );
        assert!(b.is_empty());
    }

    #[test]
    fn splits_large_request_across_batches() {
        let mut b = Batcher::new();
        b.push_request(7, 0, 10);
        let b1 = b.take(4);
        let b2 = b.take(4);
        let b3 = b.take(4);
        assert_eq!((b1.len(), b2.len(), b3.len()), (4, 4, 2));
        assert_eq!(b1[0].index, 0);
        assert_eq!(b3[1].index, 9);
        assert!(b.take(4).is_empty());
    }

    #[test]
    fn trace_context_rides_every_slot_of_its_request() {
        let mut b = Batcher::new();
        let ctx = TraceCtx { trace: 0xBEEF, span: 0xF00D };
        b.push_request_traced(1, 3, 2, ctx);
        b.push_request(2, 5, 1); // untraced neighbor
        let batch = b.take(8);
        assert_eq!(batch[0].trace, ctx);
        assert_eq!(batch[1].trace, ctx);
        assert_eq!(batch[2].trace, TraceCtx::NONE);
    }

    #[test]
    fn counters_track_flow() {
        let mut b = Batcher::new();
        b.push_request(1, 0, 5);
        b.take(3);
        assert_eq!(
            b.counters(),
            BatcherCounters { enqueued: 5, dispatched: 3, purged: 0 }
        );
        assert_eq!(b.pending(), 2);
    }

    #[test]
    fn oldest_wait_tracks_the_head_slot() {
        let t0 = Instant::now();
        let mut b = Batcher::new();
        assert_eq!(b.oldest_wait(t0), None);
        b.push_request_at(1, 0, 2, t0);
        b.push_request_at(2, 0, 1, t0 + Duration::from_millis(40));
        let now = t0 + Duration::from_millis(100);
        assert_eq!(b.oldest_wait(now), Some(Duration::from_millis(100)));
        b.take(2); // head is now the younger request
        assert_eq!(b.oldest_wait(now), Some(Duration::from_millis(60)));
        // a `now` racing behind the arrival stamp saturates to zero
        assert_eq!(b.oldest_wait(t0), Some(Duration::ZERO));
        b.take(1);
        assert_eq!(b.oldest_wait(now), None);
    }

    #[test]
    fn drop_request_purges_only_that_request() {
        let mut b = Batcher::new();
        b.push_request(1, 3, 4);
        b.push_request(2, 5, 2);
        b.push_request(3, 7, 3);
        assert_eq!(b.drop_request(2), 2);
        assert_eq!(b.pending(), 7);
        let rest = b.take(16);
        assert!(rest.iter().all(|s| s.req_id != 2));
        assert_eq!(rest.len(), 7);
        assert_eq!(b.drop_request(99), 0);
        assert_eq!(
            b.counters(),
            BatcherCounters { enqueued: 9, dispatched: 7, purged: 2 }
        );
    }

    #[test]
    fn clear_empties_the_queue_and_counts_purged() {
        let mut b = Batcher::new();
        b.push_request(1, 0, 5);
        assert_eq!(b.clear(), 5);
        assert!(b.is_empty());
        assert!(b.take(4).is_empty());
        assert_eq!(
            b.counters(),
            BatcherCounters { enqueued: 5, dispatched: 0, purged: 5 }
        );
    }

    #[test]
    fn prop_no_slot_lost_or_duplicated() {
        check("batcher conserves slots", 200, |g: &mut Gen| {
            let mut b = Batcher::new();
            let reqs = g.usize_in(1, 8);
            let mut expect = 0usize;
            for r in 0..reqs {
                let n = g.usize_in(0, 20);
                expect += n;
                b.push_request(r as u64, g.usize_in(0, 7) as i32, n);
            }
            let cap = g.usize_in(1, 16);
            let mut seen = Vec::new();
            loop {
                let batch = b.take(cap);
                if batch.is_empty() {
                    break;
                }
                assert!(batch.len() <= cap);
                seen.extend(batch);
            }
            assert_eq!(seen.len(), expect);
            // (req, index) pairs unique
            let mut keys: Vec<(u64, usize)> =
                seen.iter().map(|s| (s.req_id, s.index)).collect();
            keys.sort_unstable();
            keys.dedup();
            assert_eq!(keys.len(), expect);
            Ok(())
        });
    }

    #[test]
    fn prop_counters_conserve_through_purges() {
        // the PR-3 accounting fix: slots purged by drop_request/clear
        // no longer leave `enqueued` permanently ahead — at every
        // quiescent point enqueued == dispatched + purged + pending
        check("batcher counter conservation", 300, |g: &mut Gen| {
            let mut b = Batcher::new();
            let mut next_req = 0u64;
            for _ in 0..g.usize_in(1, 40) {
                match g.usize_in(0, 3) {
                    0 => {
                        b.push_request(next_req, 0, g.usize_in(0, 10));
                        next_req += 1;
                    }
                    1 => {
                        b.take(g.usize_in(1, 8));
                    }
                    2 => {
                        // sometimes a live request, sometimes a miss
                        let id = g.usize_in(0, (next_req as usize).max(1))
                            as u64;
                        b.drop_request(id);
                    }
                    _ => {
                        if g.usize_in(0, 9) == 0 {
                            b.clear();
                        }
                    }
                }
                let c = b.counters();
                assert_eq!(
                    c.enqueued,
                    c.dispatched + c.purged + b.pending() as u64,
                    "conservation broke: {c:?} pending {}",
                    b.pending()
                );
            }
            Ok(())
        });
    }

    #[test]
    fn prop_fifo_never_starves() {
        check("older requests always dispatch first", 100, |g: &mut Gen| {
            let mut b = Batcher::new();
            for r in 0..g.usize_in(2, 6) {
                b.push_request(r as u64, 0, g.usize_in(1, 5));
            }
            let mut last_req = 0u64;
            while !b.is_empty() {
                for s in b.take(g.usize_in(1, 4)) {
                    assert!(s.req_id >= last_req);
                    last_req = s.req_id;
                }
            }
            Ok(())
        });
    }
}
