//! Dynamic batcher: expands generation requests into per-image slots
//! and packs fixed-size batches FIFO (the sampling artifacts are
//! lowered with a fixed batch dimension, so the batcher's job is to
//! keep those slots full under mixed request sizes).

use std::collections::VecDeque;

/// One image's worth of pending work.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Slot {
    /// Originating request.
    pub req_id: u64,
    /// Class label to condition on.
    pub class: i32,
    /// Index of this image within its request.
    pub index: usize,
}

/// FIFO slot queue with fixed-batch packing.
#[derive(Debug, Default)]
pub struct Batcher {
    queue: VecDeque<Slot>,
    enqueued: u64,
    dispatched: u64,
}

impl Batcher {
    pub fn new() -> Batcher {
        Batcher::default()
    }

    /// Expand a request for `n` images of `class` into slots.
    pub fn push_request(&mut self, req_id: u64, class: i32, n: usize) {
        for index in 0..n {
            self.queue.push_back(Slot { req_id, class, index });
            self.enqueued += 1;
        }
    }

    /// Pending image slots.
    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Take up to `max_batch` slots FIFO. Returns an empty vec when idle.
    pub fn pop_batch(&mut self, max_batch: usize) -> Vec<Slot> {
        let take = self.queue.len().min(max_batch);
        let batch: Vec<Slot> = self.queue.drain(..take).collect();
        self.dispatched += batch.len() as u64;
        batch
    }

    /// (enqueued, dispatched) lifetime counters.
    pub fn counters(&self) -> (u64, u64) {
        (self.enqueued, self.dispatched)
    }

    /// Remove every queued slot belonging to `req_id` (the request
    /// failed elsewhere); returns how many slots were purged. Purged
    /// slots count as neither enqueued-anew nor dispatched.
    pub fn drop_request(&mut self, req_id: u64) -> usize {
        let before = self.queue.len();
        self.queue.retain(|s| s.req_id != req_id);
        before - self.queue.len()
    }

    /// Drop all queued slots (service aborting); returns the count.
    pub fn clear(&mut self) -> usize {
        let n = self.queue.len();
        self.queue.clear();
        n
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::{check, Gen};

    #[test]
    fn fifo_order_within_and_across_requests() {
        let mut b = Batcher::new();
        b.push_request(1, 3, 2);
        b.push_request(2, 5, 1);
        let batch = b.pop_batch(8);
        assert_eq!(
            batch,
            vec![
                Slot { req_id: 1, class: 3, index: 0 },
                Slot { req_id: 1, class: 3, index: 1 },
                Slot { req_id: 2, class: 5, index: 0 },
            ]
        );
        assert!(b.is_empty());
    }

    #[test]
    fn splits_large_request_across_batches() {
        let mut b = Batcher::new();
        b.push_request(7, 0, 10);
        let b1 = b.pop_batch(4);
        let b2 = b.pop_batch(4);
        let b3 = b.pop_batch(4);
        assert_eq!((b1.len(), b2.len(), b3.len()), (4, 4, 2));
        assert_eq!(b1[0].index, 0);
        assert_eq!(b3[1].index, 9);
        assert!(b.pop_batch(4).is_empty());
    }

    #[test]
    fn counters_track_flow() {
        let mut b = Batcher::new();
        b.push_request(1, 0, 5);
        b.pop_batch(3);
        assert_eq!(b.counters(), (5, 3));
        assert_eq!(b.pending(), 2);
    }

    #[test]
    fn drop_request_purges_only_that_request() {
        let mut b = Batcher::new();
        b.push_request(1, 3, 4);
        b.push_request(2, 5, 2);
        b.push_request(3, 7, 3);
        assert_eq!(b.drop_request(2), 2);
        assert_eq!(b.pending(), 7);
        let rest = b.pop_batch(16);
        assert!(rest.iter().all(|s| s.req_id != 2));
        assert_eq!(rest.len(), 7);
        assert_eq!(b.drop_request(99), 0);
    }

    #[test]
    fn clear_empties_the_queue() {
        let mut b = Batcher::new();
        b.push_request(1, 0, 5);
        assert_eq!(b.clear(), 5);
        assert!(b.is_empty());
        assert!(b.pop_batch(4).is_empty());
    }

    #[test]
    fn prop_no_slot_lost_or_duplicated() {
        check("batcher conserves slots", 200, |g: &mut Gen| {
            let mut b = Batcher::new();
            let reqs = g.usize_in(1, 8);
            let mut expect = 0usize;
            for r in 0..reqs {
                let n = g.usize_in(0, 20);
                expect += n;
                b.push_request(r as u64, g.usize_in(0, 7) as i32, n);
            }
            let cap = g.usize_in(1, 16);
            let mut seen = Vec::new();
            loop {
                let batch = b.pop_batch(cap);
                if batch.is_empty() {
                    break;
                }
                assert!(batch.len() <= cap);
                seen.extend(batch);
            }
            assert_eq!(seen.len(), expect);
            // (req, index) pairs unique
            let mut keys: Vec<(u64, usize)> =
                seen.iter().map(|s| (s.req_id, s.index)).collect();
            keys.sort_unstable();
            keys.dedup();
            assert_eq!(keys.len(), expect);
            Ok(())
        });
    }

    #[test]
    fn prop_fifo_never_starves() {
        check("older requests always dispatch first", 100, |g: &mut Gen| {
            let mut b = Batcher::new();
            for r in 0..g.usize_in(2, 6) {
                b.push_request(r as u64, 0, g.usize_in(1, 5));
            }
            let mut last_req = 0u64;
            while !b.is_empty() {
                for s in b.pop_batch(g.usize_in(1, 4)) {
                    assert!(s.req_id >= last_req);
                    last_req = s.req_id;
                }
            }
            Ok(())
        });
    }
}
