//! Time grouping (paper eq. 9): timesteps {0..T−1} split into G
//! contiguous groups; TGQ assigns each group its own post-softmax
//! quantization parameters, and the sampler looks up the group for the
//! current timestep to select the qparams overlay.

/// Contiguous partition of {0..T−1} into G groups,
/// 𝒢ᵢ = [ (i−1)T/G, iT/G − 1 ] (paper indexing i ∈ 1..G; ours 0-based).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TimeGroups {
    pub t_total: usize,
    pub groups: usize,
}

impl TimeGroups {
    pub fn new(t_total: usize, groups: usize) -> TimeGroups {
        assert!(groups >= 1 && groups <= t_total,
                "need 1 <= G={groups} <= T={t_total}");
        TimeGroups { t_total, groups }
    }

    /// Group index for timestep t (eq. 9): the i with
    /// ⌊iT/G⌋ ≤ t < ⌊(i+1)T/G⌋ (consistent with [`Self::range_of`] for
    /// non-divisible T).
    pub fn group_of(&self, t: usize) -> usize {
        assert!(t < self.t_total, "t={t} out of range T={}", self.t_total);
        let (tt, g) = (self.t_total, self.groups);
        let mut i = (t * g / tt).min(g - 1);
        while t < i * tt / g {
            i -= 1;
        }
        while i + 1 < g && t >= (i + 1) * tt / g {
            i += 1;
        }
        i
    }

    /// Inclusive timestep range [lo, hi] of group i.
    pub fn range_of(&self, i: usize) -> (usize, usize) {
        assert!(i < self.groups);
        let lo = i * self.t_total / self.groups;
        let hi = ((i + 1) * self.t_total / self.groups).min(self.t_total) - 1;
        (lo, hi)
    }

    /// All timesteps of group i.
    pub fn members(&self, i: usize) -> Vec<usize> {
        let (lo, hi) = self.range_of(i);
        (lo..=hi).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_defaults_partition_cleanly() {
        let tg = TimeGroups::new(250, 10);
        assert_eq!(tg.range_of(0), (0, 24));
        assert_eq!(tg.range_of(9), (225, 249));
    }

    #[test]
    fn groups_partition_without_gaps_or_overlap() {
        for (t, g) in [(250usize, 10usize), (100, 10), (97, 7), (10, 10),
                       (100, 3)] {
            let tg = TimeGroups::new(t, g);
            let mut covered = vec![0u32; t];
            for i in 0..g {
                for m in tg.members(i) {
                    covered[m] += 1;
                }
            }
            assert!(covered.iter().all(|&c| c == 1), "T={t} G={g}");
        }
    }

    #[test]
    fn group_of_agrees_with_ranges() {
        for (t, g) in [(250usize, 10usize), (100, 10), (97, 7)] {
            let tg = TimeGroups::new(t, g);
            for i in 0..g {
                for m in tg.members(i) {
                    assert_eq!(tg.group_of(m), i, "t={m} T={t} G={g}");
                }
            }
        }
    }

    #[test]
    fn group_of_monotone_in_t() {
        let tg = TimeGroups::new(250, 10);
        let mut prev = 0;
        for t in 0..250 {
            let gidx = tg.group_of(t);
            assert!(gidx >= prev);
            prev = gidx;
        }
        assert_eq!(prev, 9);
    }

    #[test]
    fn single_group_degenerates_to_global() {
        let tg = TimeGroups::new(100, 1);
        for t in 0..100 {
            assert_eq!(tg.group_of(t), 0);
        }
    }

    #[test]
    #[should_panic]
    fn rejects_more_groups_than_steps() {
        TimeGroups::new(5, 6);
    }
}
