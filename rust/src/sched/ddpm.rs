//! DDPM schedule (eq. 1–4) + strided respacing for the T=100 sampler.
//!
//! Mirrors `python/compile/train.py::betas/alpha_bars` (the model was
//! trained against that schedule). The T=100 entries in Table II come
//! from respacing the 250-step schedule: pick 100 evenly spaced original
//! timesteps and recompute betas from the ᾱ ratios — the model is always
//! conditioned on *original* timestep indices.

/// Precomputed DDPM quantities over a (possibly respaced) step sequence.
#[derive(Clone, Debug)]
pub struct DdpmSchedule {
    /// Original-model timestep index per sampler step, descending
    /// (`steps[0]` is the most-noised step the sampler starts at).
    pub steps: Vec<usize>,
    /// β per sampler step (respaced).
    pub betas: Vec<f64>,
    /// ᾱ per sampler step.
    pub alpha_bars: Vec<f64>,
    /// ᾱ of the *previous* sampler step (1.0 at the end of the chain).
    pub alpha_bars_prev: Vec<f64>,
    /// Training-schedule ᾱ over all T_train steps (forward process).
    pub train_alpha_bars: Vec<f64>,
}

impl DdpmSchedule {
    /// Linear β schedule over `t_train` steps, respaced to `t_sample`.
    pub fn new(t_train: usize, beta_start: f64, beta_end: f64,
               t_sample: usize) -> DdpmSchedule {
        assert!(t_sample >= 1 && t_sample <= t_train);
        // training schedule
        let train_betas: Vec<f64> = (0..t_train)
            .map(|i| {
                beta_start
                    + (beta_end - beta_start) * i as f64
                        / (t_train - 1).max(1) as f64
            })
            .collect();
        let mut train_abar = Vec::with_capacity(t_train);
        let mut acc = 1.0f64;
        for b in &train_betas {
            acc *= 1.0 - b;
            train_abar.push(acc);
        }

        // evenly spaced subset of original indices, ascending
        let use_steps: Vec<usize> = if t_sample == t_train {
            (0..t_train).collect()
        } else {
            (0..t_sample)
                .map(|i| i * t_train / t_sample)
                .collect()
        };

        // respaced betas from ᾱ ratios
        let mut betas = Vec::with_capacity(t_sample);
        let mut abars = Vec::with_capacity(t_sample);
        let mut abars_prev = Vec::with_capacity(t_sample);
        let mut prev = 1.0f64;
        for &s in &use_steps {
            let ab = train_abar[s];
            betas.push(1.0 - ab / prev);
            abars.push(ab);
            abars_prev.push(prev);
            prev = ab;
        }

        // sampler iterates descending
        let steps: Vec<usize> = use_steps.into_iter().rev().collect();
        betas.reverse();
        abars.reverse();
        abars_prev.reverse();

        DdpmSchedule {
            steps,
            betas,
            alpha_bars: abars,
            alpha_bars_prev: abars_prev,
            train_alpha_bars: train_abar,
        }
    }

    pub fn len(&self) -> usize {
        self.steps.len()
    }

    pub fn is_empty(&self) -> bool {
        self.steps.is_empty()
    }

    /// Forward diffusion: x_t = √ᾱ_t·x₀ + √(1−ᾱ_t)·ε for an *original*
    /// training timestep index (calibration-set construction, eq. 11).
    pub fn q_sample(&self, x0: &[f32], t: usize, eps: &[f32],
                    out: &mut [f32]) {
        let ab = self.train_alpha_bars[t];
        let (ca, ce) = (ab.sqrt() as f32, (1.0 - ab).sqrt() as f32);
        for i in 0..x0.len() {
            out[i] = ca * x0[i] + ce * eps[i];
        }
    }

    /// One reverse (ancestral) step at sampler index `i`, in place:
    /// μ = (x − β/√(1−ᾱ)·ε̂)/√α, then add σ·z for non-final steps
    /// (eq. 3/4, fixed variance σ² = β̃).
    pub fn reverse_step(&self, i: usize, x: &mut [f32], eps_hat: &[f32],
                        noise: Option<&[f32]>) {
        let beta = self.betas[i];
        let ab = self.alpha_bars[i];
        let ab_prev = self.alpha_bars_prev[i];
        let alpha = 1.0 - beta;
        let c_eps = (beta / (1.0 - ab).sqrt()) as f32;
        let c_x = (1.0 / alpha.sqrt()) as f32;
        // posterior variance β̃ = β·(1−ᾱ_prev)/(1−ᾱ)
        let var = beta * (1.0 - ab_prev) / (1.0 - ab);
        let sigma = var.max(0.0).sqrt() as f32;
        for j in 0..x.len() {
            x[j] = c_x * (x[j] - c_eps * eps_hat[j]);
        }
        if let Some(z) = noise {
            for j in 0..x.len() {
                x[j] += sigma * z[j];
            }
        }
    }

    /// Coefficients of the reverse update at sampler index `i` with
    /// PTQD variance shrinkage: returns `(c_x, c_eps, σ)` where the
    /// update is x ← c_x·(x − c_eps·ε̂) + σ·z and the residual
    /// (uncorrelated) quantization noise variance `resid_var` has been
    /// removed from the posterior σ² (floored at zero). The f32
    /// roundings deliberately reproduce [`Self::reverse_step`]'s
    /// arithmetic so a loop built on these coefficients stays
    /// byte-identical to the direct update.
    pub fn step_coeffs(&self, i: usize, resid_var: f32)
                       -> (f32, f32, f32) {
        let beta = self.betas[i];
        let ab = self.alpha_bars[i];
        let ab_prev = self.alpha_bars_prev[i];
        let alpha = 1.0 - beta;
        let c_eps = (beta / (1.0 - ab).sqrt()) as f32;
        let c_x = (1.0 / alpha.sqrt()) as f32;
        let var = beta * (1.0 - ab_prev) / (1.0 - ab);
        let var =
            (var - (c_eps as f64).powi(2) * resid_var as f64).max(0.0);
        (c_x, c_eps, var.sqrt() as f32)
    }

    /// Closed-form composition of `count` consecutive reverse steps
    /// starting at sampler index `i0`, all sharing one ε̂ (the
    /// step-reuse fast path): returns `(a, b, s)` such that
    /// x_out = a·x − b·ε̂ + s·z for a single standard gaussian z.
    ///
    /// Derivation: each step applies x ← c_x·(x − c_eps·ε̂) + σ·z_j, so
    /// the affine part composes as a ← c_x·a, b ← c_x·(b + c_eps) and
    /// the independent gaussians fold into one with
    /// s² ← c_x²·s² + σ². The trajectory-final step contributes no
    /// noise (the sampler passes `noise: None` there), which the
    /// composition honors by dropping σ when `i = len()−1`.
    pub fn fused_coeffs(&self, i0: usize, count: usize, resid_var: f32)
                        -> (f32, f32, f32) {
        let mut a = 1.0f64;
        let mut b = 0.0f64;
        let mut var = 0.0f64;
        for i in i0..(i0 + count).min(self.len()) {
            let (c_x, c_eps, sigma) = self.step_coeffs(i, resid_var);
            let (c_x, c_eps, sigma) =
                (c_x as f64, c_eps as f64, sigma as f64);
            a *= c_x;
            b = c_x * (b + c_eps);
            var = c_x * c_x * var
                + if i + 1 < self.len() { sigma * sigma } else { 0.0 };
        }
        (a as f32, b as f32, var.sqrt() as f32)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sched(t: usize) -> DdpmSchedule {
        DdpmSchedule::new(250, 1e-4, 0.02, t)
    }

    #[test]
    fn full_schedule_matches_training() {
        let s = sched(250);
        assert_eq!(s.len(), 250);
        assert_eq!(s.steps[0], 249);
        assert_eq!(*s.steps.last().unwrap(), 0);
        // respaced betas == training betas when not respaced
        assert!((s.betas.last().unwrap() - 1e-4).abs() < 1e-12);
    }

    #[test]
    fn alpha_bars_monotone_decreasing_in_t() {
        let s = sched(250);
        // sampler order is descending t → ᾱ ascending along the vec
        for i in 1..s.len() {
            assert!(s.alpha_bars[i] > s.alpha_bars[i - 1]);
        }
        assert!(s.alpha_bars[0] > 0.0 && s.alpha_bars[0] < 1.0);
    }

    #[test]
    fn respaced_100_consistent() {
        let s = sched(100);
        assert_eq!(s.len(), 100);
        // every respaced ᾱ appears in the training schedule
        for (i, &step) in s.steps.iter().enumerate() {
            assert!((s.alpha_bars[i] - s.train_alpha_bars[step]).abs()
                < 1e-15);
        }
        // β̃ stays a valid probability-ish quantity
        for &b in &s.betas {
            assert!(b > 0.0 && b < 1.0);
        }
    }

    #[test]
    fn q_sample_limits() {
        let s = sched(250);
        let x0 = vec![1.0f32; 4];
        let eps = vec![0.5f32; 4];
        let mut out = vec![0.0f32; 4];
        s.q_sample(&x0, 0, &eps, &mut out);
        // t=0: nearly clean
        assert!((out[0] - 1.0).abs() < 0.05);
        s.q_sample(&x0, 249, &eps, &mut out);
        // t=T-1: mostly noise
        let ab = s.train_alpha_bars[249];
        assert!(ab < 0.1);
        assert!((out[0] - (ab.sqrt() as f32 + (1.0 - ab).sqrt() as f32 * 0.5))
            .abs() < 1e-6);
    }

    #[test]
    fn reverse_step_denoises_perfect_prediction() {
        // at the final sampler step (t = 0), a perfect ε̂ recovers x₀
        // almost exactly: x₋ = (x_t − β/√(1−ᾱ)·ε)/√α ≈ x₀.
        let s = sched(250);
        let x0 = vec![0.8f32; 8];
        let eps = vec![0.3f32; 8];
        let i_last = s.len() - 1;
        let t = s.steps[i_last]; // == 0
        let mut xt = vec![0.0f32; 8];
        s.q_sample(&x0, t, &eps, &mut xt);
        s.reverse_step(i_last, &mut xt, &eps, None);
        for (a, b) in xt.iter().zip(&x0) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
    }

    #[test]
    fn final_step_has_zero_variance_path() {
        let s = sched(250);
        let i_last = s.len() - 1; // t = 0
        assert_eq!(s.steps[i_last], 0);
        // ᾱ_prev at the final step is 1 → posterior variance ≈ β·0
        assert!((s.alpha_bars_prev[i_last] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn step_coeffs_pin_reverse_step_rescaling() {
        // the coefficients are the closed-form pieces of eq. 3/4:
        // c_eps = β/√(1−ᾱ), c_x = 1/√α, σ² = β·(1−ᾱ_prev)/(1−ᾱ)
        let s = sched(100);
        for i in [0usize, 37, 99] {
            let (c_x, c_eps, sigma) = s.step_coeffs(i, 0.0);
            let beta = s.betas[i];
            let ab = s.alpha_bars[i];
            let ab_prev = s.alpha_bars_prev[i];
            assert_eq!(c_eps, (beta / (1.0 - ab).sqrt()) as f32);
            assert_eq!(c_x, (1.0 / (1.0 - beta).sqrt()) as f32);
            let var = beta * (1.0 - ab_prev) / (1.0 - ab);
            assert!((sigma as f64 - var.sqrt()).abs() < 1e-7);
            // a loop built on the coefficients reproduces reverse_step
            // byte-for-byte (the sampler's δ=0 exactness rests on this)
            let eps = vec![0.25f32; 4];
            let z = vec![-0.5f32; 4];
            let mut a = vec![0.7f32; 4];
            let mut b = a.clone();
            s.reverse_step(i, &mut a, &eps, Some(&z));
            for j in 0..b.len() {
                b[j] = c_x * (b[j] - c_eps * eps[j]) + sigma * z[j];
            }
            assert_eq!(a, b);
        }
    }

    #[test]
    fn step_coeffs_shrinkage_floors_sigma_at_zero() {
        let s = sched(100);
        let (_, _, sigma) = s.step_coeffs(10, 1e9);
        assert_eq!(sigma, 0.0);
    }

    #[test]
    fn fused_coeffs_match_sequential_composition() {
        // k reverse steps sharing one ε̂ collapse to x·a − ε̂·b exactly
        // (zero-noise path), for interior and trajectory-final runs
        let s = sched(100);
        for (i0, count) in [(3usize, 4usize), (0, 1), (96, 4)] {
            let eps = vec![0.3f32; 8];
            let mut x = vec![0.9f32; 8];
            for i in i0..i0 + count {
                s.reverse_step(i, &mut x, &eps, None);
            }
            let (a, b, _) = s.fused_coeffs(i0, count, 0.0);
            for &v in &x {
                let fused = a * 0.9 - b * 0.3;
                assert!((v - fused).abs() < 1e-5, "{v} vs {fused}");
            }
        }
    }

    #[test]
    fn fused_variance_composes_and_skips_final_noise() {
        let s = sched(100);
        // interior run: s² = Σ_j σ_j² · Π_{l>j} c_x_l²
        let (i0, count) = (10usize, 3usize);
        let mut want = 0.0f64;
        for j in i0..i0 + count {
            let (_, _, sigma) = s.step_coeffs(j, 0.0);
            let mut tail = 1.0f64;
            for l in j + 1..i0 + count {
                let (c_x, _, _) = s.step_coeffs(l, 0.0);
                tail *= (c_x as f64) * (c_x as f64);
            }
            want += (sigma as f64).powi(2) * tail;
        }
        let (_, _, sf) = s.fused_coeffs(i0, count, 0.0);
        assert!((sf as f64 - want.sqrt()).abs() < 1e-7);
        // a run ending on the trajectory-final step draws no noise there
        let (_, _, s_last) = s.fused_coeffs(s.len() - 1, 1, 0.0);
        assert_eq!(s_last, 0.0);
    }
}
