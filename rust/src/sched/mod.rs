//! Diffusion scheduling substrate.

pub mod ddpm;
pub mod timegroups;

pub use ddpm::DdpmSchedule;
pub use timegroups::TimeGroups;
