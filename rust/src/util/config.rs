//! Run configuration: a TOML-subset file format + typed config structs.
//!
//! No `serde`/`toml` offline, so we parse a pragmatic subset —
//! `[section]` headers, `key = value` with string/int/float/bool values,
//! `#` comments — which covers everything the launcher needs. Any CLI
//! option `--key value` overrides the file (section-qualified keys use
//! `section.key`).

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::cli::Args;

/// Flat `section.key → raw string value` map.
#[derive(Clone, Debug, Default)]
pub struct RawConfig {
    pub values: BTreeMap<String, String>,
}

impl RawConfig {
    pub fn parse(text: &str) -> Result<RawConfig> {
        let mut values = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = match raw.find('#') {
                Some(i) => &raw[..i],
                None => raw,
            }
            .trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .with_context(|| format!("line {}: bad section", lineno + 1))?;
                section = name.trim().to_string();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .with_context(|| format!("line {}: expected key = value", lineno + 1))?;
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{}.{}", section, k.trim())
            };
            let mut val = v.trim().to_string();
            if (val.starts_with('"') && val.ends_with('"') && val.len() >= 2)
                || (val.starts_with('\'') && val.ends_with('\'') && val.len() >= 2)
            {
                val = val[1..val.len() - 1].to_string();
            }
            if values.insert(key.clone(), val).is_some() {
                bail!("line {}: duplicate key `{}`", lineno + 1, key);
            }
        }
        Ok(RawConfig { values })
    }

    pub fn load(path: &Path) -> Result<RawConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        Self::parse(&text)
    }

    /// Overlay CLI options (CLI wins).
    pub fn overlay(&mut self, args: &Args) {
        for (k, v) in &args.options {
            self.values.insert(k.clone(), v.clone());
        }
    }

    pub fn usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.values.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| {
                format!("config `{key}`: expected an integer, got `{v}`")
            }),
        }
    }

    pub fn f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.values.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| {
                format!("config `{key}`: expected a float, got `{v}`")
            }),
        }
    }

    pub fn bool(&self, key: &str, default: bool) -> Result<bool> {
        match self.values.get(key).map(|s| s.as_str()) {
            None => Ok(default),
            Some("true") | Some("1") | Some("yes") => Ok(true),
            Some("false") | Some("0") | Some("no") => Ok(false),
            Some(v) => {
                bail!("config `{key}`: expected a boolean, got `{v}`")
            }
        }
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.values.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    /// Comma-separated positive-integer list (`"1,2,4"`); `None` when
    /// the key is absent.
    pub fn usize_list(&self, key: &str) -> Result<Option<Vec<usize>>> {
        let Some(v) = self.values.get(key) else { return Ok(None) };
        let list = v
            .split(',')
            .map(str::trim)
            .filter(|s| !s.is_empty())
            .map(|s| {
                s.parse::<usize>().with_context(|| {
                    format!(
                        "config `{key}`: expected a comma-separated \
                         integer list, got `{v}`"
                    )
                })
            })
            .collect::<Result<Vec<usize>>>()?;
        if list.is_empty() {
            bail!("config `{key}`: expected at least one integer, \
                   got `{v}`");
        }
        Ok(Some(list))
    }

    /// Comma-separated string list (`"host:7070,host:7071"`); `None`
    /// when the key is absent, an error when it is present but holds
    /// no entries.
    pub fn str_list(&self, key: &str) -> Result<Option<Vec<String>>> {
        let Some(v) = self.values.get(key) else { return Ok(None) };
        let list: Vec<String> = v
            .split(',')
            .map(str::trim)
            .filter(|s| !s.is_empty())
            .map(str::to_string)
            .collect();
        if list.is_empty() {
            bail!("config `{key}`: expected at least one entry, \
                   got `{v}`");
        }
        Ok(Some(list))
    }
}

/// Everything the quantization pipeline needs; built from file + CLI.
#[derive(Clone, Debug)]
pub struct RunConfig {
    /// Directory with AOT artifacts (manifest.json etc.).
    pub artifacts: String,
    /// Weight / activation bit-width (paper: 8 or 6).
    pub wbits: u32,
    pub abits: u32,
    /// Sampler steps T (paper: 250 or 100).
    pub timesteps: usize,
    /// Time groups G (paper: 10).
    pub groups: usize,
    /// Calibration samples per group n (paper: 32).
    pub calib_per_group: usize,
    /// Alternating optimization rounds R (paper: 3).
    pub rounds: usize,
    /// Candidate grid size for scale search.
    pub candidates: usize,
    /// Images to generate for FID/IS evaluation.
    pub eval_images: usize,
    /// RNG seed.
    pub seed: u64,
    /// Feature toggles (ablation, Table III).
    pub use_ho: bool,
    pub use_mrq: bool,
    pub use_tgq: bool,
    /// Persistent calibration-cache directory (`--calib-cache DIR`);
    /// `None` (`--no-calib-cache`) disables load *and* store.
    pub calib_cache: Option<String>,
    /// Serve: restrict workers to these lowered batch rungs
    /// (`--batch-ladder 1,2,4`); `None` serves every rung in the
    /// manifest. Rungs not lowered in the artifacts fail worker init
    /// with a typed error.
    pub batch_ladder: Option<Vec<usize>>,
    /// Serve: how long a partially-filled batch rung may wait for more
    /// slots before dispatching padded (`--linger-ms N`). Zero (the
    /// default) dispatches immediately — byte-identical to the
    /// pre-ladder fixed-batch behavior on one-rung manifests.
    pub linger_ms: u64,
    /// Serve: shard-node addresses (`--shards host:7070,host:7071`).
    /// `None` serves in-process; `Some` makes `serve` a cluster
    /// frontend dispatching over the net layer.
    pub shards: Option<Vec<String>>,
    /// Cluster heartbeat cadence (`--heartbeat-ms N`).
    pub heartbeat_ms: u64,
    /// Cluster node-loss deadline (`--node-timeout-ms N`): a shard
    /// whose last heartbeat is older than this is declared dead and
    /// its in-flight requests re-queued. Must exceed the heartbeat.
    pub node_timeout_ms: u64,
    /// Cluster: dedicated control connection per shard for
    /// ping/pong/stats (`--control-plane BOOL`, default true), so
    /// liveness never queues behind multi-MiB response frames.
    /// `false` is the pre-isolation shared-connection *topology*
    /// (diagnostic baseline; both ends still run the same build).
    pub control_plane: bool,
    /// Cluster: consecutive pongs a reconnected shard must answer
    /// before re-admission into placement (`--readmit-pongs K`).
    pub readmit_pongs: u32,
    /// Cluster: how often dead shards are re-dialed
    /// (`--reconnect-ms N`).
    pub reconnect_ms: u64,
    /// Sampler: step-reuse drift threshold δ (`--reuse-delta X`). Time
    /// groups whose calibrated ε-drift sits strictly below δ share
    /// forward passes across adjacent steps (the skipped reverse
    /// updates are applied in closed form). 0 disables reuse and is
    /// byte-identical to the per-step loop.
    pub reuse_delta: f64,
    /// Serve/cluster: event-driven transport (`--reactor BOOL`,
    /// default on). One `poll(2)` reactor thread per process owns
    /// every connection instead of one handler thread each; same wire
    /// protocol, so mixed deployments interoperate. `--reactor false`
    /// falls back to the thread-per-connection transport.
    pub reactor: bool,
    /// Node: accepted-connection cap in reactor mode
    /// (`--max-conns N`); connections past the cap are refused at
    /// accept. Ignored by the thread-per-connection transport.
    pub max_conns: usize,
    /// Observability: request-scoped tracing (`--trace BOOL`, default
    /// off). Spans cover queue/linger/rung-pick/generate/encode and —
    /// on a cluster frontend — the per-shard dispatch hop; nodes ship
    /// their spans home on the response so one request is one
    /// timeline.
    pub trace: bool,
    /// Observability: write the collected spans as Chrome
    /// `chrome://tracing` JSON here on shutdown
    /// (`--trace-json PATH`). Implies `--trace true`.
    pub trace_json: Option<String>,
    /// Observability: serve a Prometheus text exposition on this
    /// address (`--metrics-addr host:port`). Reactor-mode nodes only;
    /// `None` (the default) binds nothing.
    pub metrics_addr: Option<String>,
    /// Stderr log threshold (`--log-level debug|info|warn|error`).
    pub log_level: String,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            artifacts: "artifacts".into(),
            wbits: 8,
            abits: 8,
            timesteps: 250,
            groups: 10,
            calib_per_group: 32,
            rounds: 3,
            candidates: 80,
            eval_images: 256,
            seed: 0,
            use_ho: true,
            use_mrq: true,
            use_tgq: true,
            calib_cache: Some("calib-cache".into()),
            batch_ladder: None,
            linger_ms: 0,
            shards: None,
            heartbeat_ms: 500,
            node_timeout_ms: 2500,
            control_plane: true,
            readmit_pongs: 3,
            reconnect_ms: 1000,
            reuse_delta: 0.05,
            reactor: true,
            max_conns: 4096,
            trace: false,
            trace_json: None,
            metrics_addr: None,
            log_level: "info".into(),
        }
    }
}

impl RunConfig {
    pub fn from_raw(raw: &RawConfig) -> Result<RunConfig> {
        let d = RunConfig::default();
        let calib_cache = if raw.bool("no-calib-cache", false)? {
            None
        } else {
            Some(raw.str_or(
                "calib-cache",
                d.calib_cache.as_deref().unwrap_or("calib-cache"),
            ))
        };
        let mut cfg = RunConfig {
            artifacts: raw.str_or("artifacts", &d.artifacts),
            wbits: raw.usize("wbits", d.wbits as usize)? as u32,
            abits: raw.usize("abits", d.abits as usize)? as u32,
            timesteps: raw.usize("timesteps", d.timesteps)?,
            groups: raw.usize("groups", d.groups)?,
            calib_per_group: raw
                .usize("calib-per-group", d.calib_per_group)?,
            rounds: raw.usize("rounds", d.rounds)?,
            candidates: raw.usize("candidates", d.candidates)?,
            eval_images: raw.usize("eval-images", d.eval_images)?,
            seed: raw.usize("seed", d.seed as usize)? as u64,
            use_ho: raw.bool("ho", d.use_ho)?,
            use_mrq: raw.bool("mrq", d.use_mrq)?,
            use_tgq: raw.bool("tgq", d.use_tgq)?,
            calib_cache,
            batch_ladder: match raw.usize_list("batch-ladder")? {
                None => d.batch_ladder,
                Some(mut v) => {
                    if v.contains(&0) {
                        bail!("config `batch-ladder`: rungs must be \
                               positive");
                    }
                    v.sort_unstable();
                    v.dedup();
                    Some(v)
                }
            },
            linger_ms: raw.usize("linger-ms", d.linger_ms as usize)? as u64,
            shards: raw.str_list("shards")?,
            heartbeat_ms: raw
                .usize("heartbeat-ms", d.heartbeat_ms as usize)?
                as u64,
            node_timeout_ms: raw
                .usize("node-timeout-ms", d.node_timeout_ms as usize)?
                as u64,
            control_plane: raw.bool("control-plane", d.control_plane)?,
            readmit_pongs: raw
                .usize("readmit-pongs", d.readmit_pongs as usize)?
                as u32,
            reconnect_ms: raw
                .usize("reconnect-ms", d.reconnect_ms as usize)?
                as u64,
            reuse_delta: raw.f64("reuse-delta", d.reuse_delta)?,
            reactor: raw.bool("reactor", d.reactor)?,
            max_conns: raw.usize("max-conns", d.max_conns)?,
            trace: raw.bool("trace", d.trace)?,
            trace_json: raw.values.get("trace-json").cloned(),
            metrics_addr: raw.values.get("metrics-addr").cloned(),
            log_level: raw.str_or("log-level", &d.log_level),
        };
        // an export path without spans would be an empty file; asking
        // for the file is asking for the spans
        if cfg.trace_json.is_some() {
            cfg.trace = true;
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Cross-field checks that would otherwise surface as panics deep
    /// in calibration: every time group must be able to cover at least
    /// one sampler step.
    pub fn validate(&self) -> Result<()> {
        if self.timesteps == 0 {
            bail!("config `timesteps`: must be at least 1");
        }
        if self.groups == 0 {
            bail!("config `groups`: must be at least 1");
        }
        if self.groups > self.timesteps {
            bail!(
                "config: groups (G={}) exceeds sampler timesteps (T={}) — \
                 some time group would cover no sampler steps; lower \
                 `groups` or raise `timesteps`",
                self.groups, self.timesteps
            );
        }
        if self.heartbeat_ms == 0 {
            bail!("config `heartbeat-ms`: must be at least 1");
        }
        if self.node_timeout_ms <= self.heartbeat_ms {
            bail!(
                "config: node-timeout-ms ({}) must exceed heartbeat-ms \
                 ({}) — a timeout within one heartbeat declares every \
                 healthy node dead",
                self.node_timeout_ms, self.heartbeat_ms
            );
        }
        if self.readmit_pongs == 0 {
            bail!("config `readmit-pongs`: must be at least 1 — zero \
                   would re-admit a shard before it answered anything");
        }
        if self.reconnect_ms == 0 {
            bail!("config `reconnect-ms`: must be at least 1");
        }
        if self.max_conns == 0 {
            bail!("config `max-conns`: must be at least 1 — a zero cap \
                   refuses every connection at accept");
        }
        if !self.reuse_delta.is_finite() || self.reuse_delta < 0.0 {
            bail!(
                "config `reuse-delta`: must be a finite value >= 0 \
                 (got {}); 0 disables step reuse",
                self.reuse_delta
            );
        }
        match self.log_level.to_ascii_lowercase().as_str() {
            "debug" | "info" | "warn" | "warning" | "error" => {}
            other => bail!(
                "config `log-level`: unknown level `{other}` \
                 (expected debug|info|warn|error)"
            ),
        }
        if let Some(p) = &self.trace_json {
            if p.is_empty() {
                bail!("config `trace-json`: expected a file path");
            }
        }
        if let Some(a) = &self.metrics_addr {
            if !a.contains(':') {
                bail!(
                    "config `metrics-addr`: expected host:port, \
                     got `{a}`"
                );
            }
        }
        Ok(())
    }

    /// file (optional `--config path`) + CLI overlay.
    pub fn from_args(args: &Args) -> Result<RunConfig> {
        let mut raw = match args.get("config") {
            Some(p) => RawConfig::load(Path::new(p))?,
            None => RawConfig::default(),
        };
        raw.overlay(args);
        RunConfig::from_raw(&raw)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_comments() {
        let text = r#"
# top comment
wbits = 6
[eval]
images = 128   # inline comment
name = "full run"
"#;
        let c = RawConfig::parse(text).unwrap();
        assert_eq!(c.usize("wbits", 0).unwrap(), 6);
        assert_eq!(c.usize("eval.images", 0).unwrap(), 128);
        assert_eq!(c.str_or("eval.name", ""), "full run");
    }

    #[test]
    fn rejects_duplicates_and_bad_lines() {
        assert!(RawConfig::parse("a = 1\na = 2").is_err());
        assert!(RawConfig::parse("nonsense").is_err());
        assert!(RawConfig::parse("[open").is_err());
    }

    #[test]
    fn cli_overlay_wins() {
        let mut c = RawConfig::parse("wbits = 8").unwrap();
        let args = super::super::cli::Args::parse(
            ["--wbits", "6"].iter().map(|s| s.to_string()),
        );
        c.overlay(&args);
        assert_eq!(c.usize("wbits", 0).unwrap(), 6);
    }

    #[test]
    fn malformed_values_error_with_key_and_value() {
        let c = RawConfig::parse("wbits = eight\nrate = slow\nho = maybe")
            .unwrap();
        let e = c.usize("wbits", 0).unwrap_err().to_string();
        assert!(e.contains("wbits") && e.contains("eight"), "{e}");
        let e = c.f64("rate", 0.0).unwrap_err().to_string();
        assert!(e.contains("rate") && e.contains("slow"), "{e}");
        let e = c.bool("ho", true).unwrap_err().to_string();
        assert!(e.contains("ho") && e.contains("maybe"), "{e}");
        // malformed file-level values surface through RunConfig too
        assert!(RunConfig::from_raw(&c).is_err());
    }

    #[test]
    fn bool_accepts_both_polarities() {
        let c = RawConfig::parse("a = true\nb = no\nc = 0").unwrap();
        assert!(c.bool("a", false).unwrap());
        assert!(!c.bool("b", true).unwrap());
        assert!(!c.bool("c", true).unwrap());
        assert!(c.bool("missing", true).unwrap());
    }

    #[test]
    fn runconfig_defaults_match_paper() {
        let d = RunConfig::default();
        assert_eq!(d.groups, 10);
        assert_eq!(d.calib_per_group, 32);
        assert_eq!(d.rounds, 3);
        assert_eq!(d.timesteps, 250);
    }

    #[test]
    fn rejects_groupings_no_sampler_respacing_can_satisfy() {
        // G > T: some group would cover no sampler step — caught at
        // config-parse time, not as a worker panic mid-calibration
        let c = RawConfig::parse("groups = 20\ntimesteps = 10").unwrap();
        let e = RunConfig::from_raw(&c).unwrap_err().to_string();
        assert!(e.contains("G=20") && e.contains("T=10"), "{e}");
        for bad in ["groups = 0", "timesteps = 0"] {
            let c = RawConfig::parse(bad).unwrap();
            assert!(RunConfig::from_raw(&c).is_err(), "{bad}");
        }
        // boundary: G == T is fine (one step per group)
        let c = RawConfig::parse("groups = 10\ntimesteps = 10").unwrap();
        assert!(RunConfig::from_raw(&c).is_ok());
    }

    #[test]
    fn batch_ladder_and_linger_flags() {
        // defaults: serve every lowered rung, dispatch immediately
        let cfg = RunConfig::from_raw(&RawConfig::parse("").unwrap())
            .unwrap();
        assert_eq!(cfg.batch_ladder, None);
        assert_eq!(cfg.linger_ms, 0);
        // --batch-ladder 4,1,2,2 sorts + dedups
        let c = RawConfig::parse("batch-ladder = 4,1,2,2\nlinger-ms = 15")
            .unwrap();
        let cfg = RunConfig::from_raw(&c).unwrap();
        assert_eq!(cfg.batch_ladder, Some(vec![1, 2, 4]));
        assert_eq!(cfg.linger_ms, 15);
        // malformed values error with the key and value
        let c = RawConfig::parse("batch-ladder = 1,x").unwrap();
        let e = format!("{:#}", RunConfig::from_raw(&c).unwrap_err());
        assert!(e.contains("batch-ladder") && e.contains("1,x"), "{e}");
        let c = RawConfig::parse("batch-ladder = 0,4").unwrap();
        assert!(RunConfig::from_raw(&c).is_err());
        let c = RawConfig::parse("batch-ladder = ,").unwrap();
        assert!(RunConfig::from_raw(&c).is_err());
    }

    #[test]
    fn shards_and_health_flags() {
        // defaults: in-process serving, paper-agnostic net timings
        let cfg = RunConfig::from_raw(&RawConfig::parse("").unwrap())
            .unwrap();
        assert_eq!(cfg.shards, None);
        assert_eq!(cfg.heartbeat_ms, 500);
        assert_eq!(cfg.node_timeout_ms, 2500);
        // --shards splits, trims, and keeps order
        let c = RawConfig::parse(
            "shards = 10.0.0.1:7070, 10.0.0.2:7070\nheartbeat-ms = 100\n\
             node-timeout-ms = 900",
        )
        .unwrap();
        let cfg = RunConfig::from_raw(&c).unwrap();
        assert_eq!(
            cfg.shards.as_deref(),
            Some(&["10.0.0.1:7070".to_string(),
                   "10.0.0.2:7070".to_string()][..])
        );
        assert_eq!((cfg.heartbeat_ms, cfg.node_timeout_ms), (100, 900));
        // elasticity knobs default to isolated + recoverable
        assert!(cfg.control_plane);
        assert_eq!(cfg.readmit_pongs, 3);
        assert_eq!(cfg.reconnect_ms, 1000);
        // an empty shard list is a config error, not "no shards"
        let c = RawConfig::parse("shards = ,").unwrap();
        let e = format!("{:#}", RunConfig::from_raw(&c).unwrap_err());
        assert!(e.contains("shards"), "{e}");
        // a timeout within one heartbeat would kill every healthy node
        let c = RawConfig::parse("heartbeat-ms = 500\n\
                                  node-timeout-ms = 500")
            .unwrap();
        let e = format!("{:#}", RunConfig::from_raw(&c).unwrap_err());
        assert!(e.contains("node-timeout-ms"), "{e}");
        let c = RawConfig::parse("heartbeat-ms = 0").unwrap();
        assert!(RunConfig::from_raw(&c).is_err());
    }

    #[test]
    fn control_plane_and_readmission_flags() {
        let c = RawConfig::parse(
            "control-plane = false\nreadmit-pongs = 5\n\
             reconnect-ms = 250",
        )
        .unwrap();
        let cfg = RunConfig::from_raw(&c).unwrap();
        assert!(!cfg.control_plane);
        assert_eq!(cfg.readmit_pongs, 5);
        assert_eq!(cfg.reconnect_ms, 250);
        // zero would re-admit untested shards / spin the re-dialer
        for bad in ["readmit-pongs = 0", "reconnect-ms = 0"] {
            let c = RawConfig::parse(bad).unwrap();
            assert!(RunConfig::from_raw(&c).is_err(), "{bad}");
        }
        // malformed values error with the key and value
        let c = RawConfig::parse("readmit-pongs = many").unwrap();
        let e = format!("{:#}", RunConfig::from_raw(&c).unwrap_err());
        assert!(e.contains("readmit-pongs") && e.contains("many"), "{e}");
    }

    #[test]
    fn reactor_and_max_conns_flags() {
        // defaults: event-driven reactor transport (soaked in CI —
        // ROADMAP carry-over), roomy cap
        let cfg = RunConfig::from_raw(&RawConfig::parse("").unwrap())
            .unwrap();
        assert!(cfg.reactor);
        assert_eq!(cfg.max_conns, 4096);
        // `--reactor false` opts back into thread-per-connection; the
        // cap is tunable
        let c = RawConfig::parse("reactor = false\nmax-conns = 2000")
            .unwrap();
        let cfg = RunConfig::from_raw(&c).unwrap();
        assert!(!cfg.reactor);
        assert_eq!(cfg.max_conns, 2000);
        // a zero cap would refuse every connection
        let c = RawConfig::parse("max-conns = 0").unwrap();
        assert!(RunConfig::from_raw(&c).is_err());
        // malformed values error with the key and value
        let c = RawConfig::parse("max-conns = lots").unwrap();
        let e = format!("{:#}", RunConfig::from_raw(&c).unwrap_err());
        assert!(e.contains("max-conns") && e.contains("lots"), "{e}");
    }

    #[test]
    fn reuse_delta_flag() {
        // default: a small positive δ — low-drift groups reuse; 0 is
        // the exactness anchor
        let cfg = RunConfig::from_raw(&RawConfig::parse("").unwrap())
            .unwrap();
        assert_eq!(cfg.reuse_delta, 0.05);
        let c = RawConfig::parse("reuse-delta = 0").unwrap();
        assert_eq!(RunConfig::from_raw(&c).unwrap().reuse_delta, 0.0);
        let c = RawConfig::parse("reuse-delta = 0.125").unwrap();
        assert_eq!(RunConfig::from_raw(&c).unwrap().reuse_delta, 0.125);
        // negative, non-finite and malformed values are config errors
        for bad in ["reuse-delta = -0.1", "reuse-delta = inf",
                    "reuse-delta = NaN"] {
            let c = RawConfig::parse(bad).unwrap();
            assert!(RunConfig::from_raw(&c).is_err(), "{bad}");
        }
        let c = RawConfig::parse("reuse-delta = slow").unwrap();
        let e = format!("{:#}", RunConfig::from_raw(&c).unwrap_err());
        assert!(e.contains("reuse-delta") && e.contains("slow"), "{e}");
    }

    #[test]
    fn observability_flags() {
        // defaults: tracing off, no export, no metrics endpoint,
        // info-level logs — the hot path pays nothing it didn't ask for
        let cfg = RunConfig::from_raw(&RawConfig::parse("").unwrap())
            .unwrap();
        assert!(!cfg.trace);
        assert_eq!(cfg.trace_json, None);
        assert_eq!(cfg.metrics_addr, None);
        assert_eq!(cfg.log_level, "info");
        // --trace-json implies --trace: asking for the file is asking
        // for the spans
        let c = RawConfig::parse("trace-json = /tmp/spans.json").unwrap();
        let cfg = RunConfig::from_raw(&c).unwrap();
        assert!(cfg.trace);
        assert_eq!(cfg.trace_json.as_deref(), Some("/tmp/spans.json"));
        // explicit knobs round-trip
        let c = RawConfig::parse(
            "trace = true\nmetrics-addr = 127.0.0.1:9091\n\
             log-level = debug",
        )
        .unwrap();
        let cfg = RunConfig::from_raw(&c).unwrap();
        assert!(cfg.trace);
        assert_eq!(cfg.metrics_addr.as_deref(), Some("127.0.0.1:9091"));
        assert_eq!(cfg.log_level, "debug");
        // malformed values are config errors with the key in them
        let c = RawConfig::parse("log-level = loud").unwrap();
        let e = format!("{:#}", RunConfig::from_raw(&c).unwrap_err());
        assert!(e.contains("log-level") && e.contains("loud"), "{e}");
        let c = RawConfig::parse("metrics-addr = 9091").unwrap();
        let e = format!("{:#}", RunConfig::from_raw(&c).unwrap_err());
        assert!(e.contains("metrics-addr"), "{e}");
        let c = RawConfig::parse("trace-json = \"\"").unwrap();
        assert!(RunConfig::from_raw(&c).is_err());
    }

    #[test]
    fn calib_cache_flags() {
        // default: enabled at the conventional directory
        let c = RawConfig::parse("").unwrap();
        let cfg = RunConfig::from_raw(&c).unwrap();
        assert_eq!(cfg.calib_cache.as_deref(), Some("calib-cache"));
        // --calib-cache DIR overrides the location
        let c = RawConfig::parse("calib-cache = /tmp/tqdit-cc").unwrap();
        let cfg = RunConfig::from_raw(&c).unwrap();
        assert_eq!(cfg.calib_cache.as_deref(), Some("/tmp/tqdit-cc"));
        // --no-calib-cache disables it (bare CLI flags parse as "true")
        let c = RawConfig::parse("no-calib-cache = true").unwrap();
        let cfg = RunConfig::from_raw(&c).unwrap();
        assert_eq!(cfg.calib_cache, None);
    }
}
