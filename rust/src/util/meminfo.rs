//! Process memory probes via `/proc/self/status` (Table IV substrate).
//!
//! The paper reports calibration GPU memory; on this CPU testbed the
//! analogous quantity is peak resident set size (VmHWM) attributable to
//! the calibration phase. `MemProbe` snapshots VmHWM around a region.

/// Parse a `VmXXX:  1234 kB`-style line value in bytes.
fn parse_kb_line(line: &str) -> Option<u64> {
    let mut parts = line.split_whitespace();
    let _label = parts.next()?;
    let value: u64 = parts.next()?.parse().ok()?;
    Some(value * 1024)
}

/// Current resident set size in bytes (VmRSS).
pub fn current_rss() -> Option<u64> {
    let text = std::fs::read_to_string("/proc/self/status").ok()?;
    text.lines()
        .find(|l| l.starts_with("VmRSS:"))
        .and_then(parse_kb_line)
}

/// Peak resident set size in bytes (VmHWM).
pub fn peak_rss() -> Option<u64> {
    let text = std::fs::read_to_string("/proc/self/status").ok()?;
    text.lines()
        .find(|l| l.starts_with("VmHWM:"))
        .and_then(parse_kb_line)
}

/// Region-scoped memory probe: RSS growth + wall time.
pub struct MemProbe {
    rss_before: u64,
    peak_before: u64,
    start: std::time::Instant,
}

/// What a probed region cost.
#[derive(Clone, Copy, Debug)]
pub struct RegionCost {
    /// RSS delta across the region (bytes; ≥ 0).
    pub rss_delta: u64,
    /// Peak RSS observed during the region (bytes).
    pub peak: u64,
    pub wall_s: f64,
}

impl MemProbe {
    pub fn start() -> MemProbe {
        MemProbe {
            rss_before: current_rss().unwrap_or(0),
            peak_before: peak_rss().unwrap_or(0),
            start: std::time::Instant::now(),
        }
    }

    pub fn finish(self) -> RegionCost {
        let rss_after = current_rss().unwrap_or(0);
        let peak_after = peak_rss().unwrap_or(0);
        RegionCost {
            rss_delta: rss_after.saturating_sub(self.rss_before),
            peak: peak_after.max(self.peak_before),
            wall_s: self.start.elapsed().as_secs_f64(),
        }
    }
}

pub fn fmt_bytes(b: u64) -> String {
    if b >= 1 << 30 {
        format!("{:.2} GiB", b as f64 / (1u64 << 30) as f64)
    } else if b >= 1 << 20 {
        format!("{:.2} MiB", b as f64 / (1u64 << 20) as f64)
    } else {
        format!("{:.1} KiB", b as f64 / 1024.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn probes_read_proc() {
        // Linux-only environment per the brief.
        assert!(current_rss().unwrap() > 0);
        assert!(peak_rss().unwrap() >= current_rss().unwrap());
    }

    #[test]
    fn region_cost_tracks_allocation() {
        let probe = MemProbe::start();
        let v: Vec<u8> = vec![1; 32 << 20]; // 32 MiB
        std::hint::black_box(&v);
        let cost = probe.finish();
        drop(v);
        assert!(cost.wall_s >= 0.0);
        assert!(cost.peak > 0);
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_bytes(512), "0.5 KiB");
        assert!(fmt_bytes(3 << 20).contains("MiB"));
        assert!(fmt_bytes(3 << 30).contains("GiB"));
    }
}
