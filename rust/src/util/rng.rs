//! Deterministic PRNG substrate (no `rand` offline).
//!
//! `Rng` is xoshiro256++ seeded via SplitMix64, with Box–Muller normal
//! sampling. Streams are cheaply splittable so calibration, sampling and
//! the serve loop each own an independent deterministic stream.

/// xoshiro256++ with a SplitMix64 seeder and a Box–Muller gaussian cache.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    cached_normal: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed the generator; any u64 works (including 0).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, cached_normal: None }
    }

    /// Derive an independent stream (used per-worker / per-phase).
    pub fn split(&mut self) -> Rng {
        Rng::new(self.next_u64() ^ 0xA02_4C0_1B3_15u64.rotate_left(17))
    }

    /// Next raw 64 random bits (xoshiro256++).
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [lo, hi).
    pub fn uniform_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform() as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free for our sizes: 128-bit multiply.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Box–Muller (caches the second variate).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.cached_normal.take() {
            return z;
        }
        let mut u1 = self.uniform();
        while u1 <= f64::MIN_POSITIVE {
            u1 = self.uniform();
        }
        let u2 = self.uniform();
        let r = (-2.0 * u1.ln()).sqrt();
        let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
        self.cached_normal = Some(r * s);
        r * c
    }

    /// Fill a slice with standard normals (f32).
    pub fn fill_normal(&mut self, out: &mut [f32]) {
        for v in out.iter_mut() {
            *v = self.normal() as f32;
        }
    }

    /// Vector of standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        let mut v = vec![0.0f32; n];
        self.fill_normal(&mut v);
        v
    }

    /// Sample `k` distinct indices from [0, n) (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(9);
        for n in [1usize, 2, 7, 100] {
            for _ in 0..1000 {
                assert!(r.below(n) < n);
            }
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let z = r.normal();
            sum += z;
            sq += z * z;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(5);
        let idx = r.sample_indices(50, 20);
        let mut seen = std::collections::HashSet::new();
        for i in &idx {
            assert!(*i < 50);
            assert!(seen.insert(*i));
        }
        assert_eq!(idx.len(), 20);
    }

    #[test]
    fn split_streams_independent() {
        let mut a = Rng::new(11);
        let mut b = a.split();
        // Not a strict independence test — just divergence.
        let xs: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(xs, ys);
    }
}
