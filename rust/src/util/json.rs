//! Minimal JSON parser + serializer (no `serde` offline) — reads
//! `manifest.json` and persists calibration-cache entries.
//!
//! Supports the full JSON value grammar (objects, arrays, strings with
//! escapes, numbers, bools, null). Error messages carry byte offsets.
//! [`Json::dump`] emits compact text that parses back to an identical
//! value: floats use Rust's shortest-roundtrip `Display`, so every
//! finite `f64` (and every `f32` widened to `f64`) survives a
//! serialize → parse cycle bit-for-bit. Non-finite numbers are not
//! representable in JSON and serialize as `null`; typed readers then
//! reject the field instead of silently reading garbage.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with a byte offset into the input.
#[derive(Debug)]
pub struct JsonError {
    pub offset: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    /// Serialize to compact JSON text (see module docs for the
    /// round-trip and non-finite-number guarantees).
    pub fn dump(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(x) => {
                if x.is_finite() {
                    // Display is shortest-roundtrip and never uses
                    // exponent notation, both of which JSON needs
                    out.push_str(&x.to_string());
                } else {
                    out.push_str("null");
                }
            }
            Json::Str(s) => write_string(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_string(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    // -- typed accessors ---------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// Lossy cast (fraction truncated, negatives saturate) — legacy
    /// accessor; strict loaders should use [`Self::as_exact_usize`].
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    /// Integer-valued number → usize. `None` for fractional or
    /// negative values, and from 2^53 up (the first value where f64
    /// can no longer distinguish adjacent integers) — the accessor
    /// validating loaders use so corruption errors instead of silently
    /// truncating.
    pub fn as_exact_usize(&self) -> Option<usize> {
        let x = self.as_f64()?;
        if x.fract() != 0.0 || x < 0.0 || x >= 9_007_199_254_740_992.0 {
            return None;
        }
        Some(x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Array of whole numbers → Vec<usize> (shapes).
    pub fn as_shape(&self) -> Option<Vec<usize>> {
        self.as_arr()?
            .iter()
            .map(|v| v.as_exact_usize())
            .collect::<Option<Vec<_>>>()
    }
}

/// Write `s` as a JSON string literal, escaping per RFC 8259.
fn write_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { offset: self.i, msg: msg.to_string() }
    }

    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected `{}`", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected `{word}`")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let c = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match c {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(
                                &self.b[self.i..self.i + 4],
                            )
                            .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            s.push(
                                char::from_u32(cp)
                                    .ok_or_else(|| self.err("bad codepoint"))?,
                            );
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let start = self.i;
                    self.i += 1;
                    while self.i < self.b.len()
                        && (self.b[self.i] & 0xC0) == 0x80
                    {
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".into())
        );
    }

    #[test]
    fn nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": false}"#)
            .unwrap();
        assert_eq!(v.get("c").unwrap().as_bool(), Some(false));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn shapes() {
        let v = Json::parse("[8, 64, 96]").unwrap();
        assert_eq!(v.as_shape(), Some(vec![8, 64, 96]));
        // fractional / negative dims are corruption, not shapes
        assert_eq!(Json::parse("[8, 2.5]").unwrap().as_shape(), None);
        assert_eq!(Json::parse("[-1]").unwrap().as_shape(), None);
    }

    #[test]
    fn exact_usize_rejects_non_integers() {
        assert_eq!(Json::Num(8.0).as_exact_usize(), Some(8));
        assert_eq!(Json::Num(0.0).as_exact_usize(), Some(0));
        assert_eq!(Json::Num(8.7).as_exact_usize(), None);
        assert_eq!(Json::Num(-1.0).as_exact_usize(), None);
        assert_eq!(Json::Num(1e300).as_exact_usize(), None);
        // 2^53 itself is ambiguous (2^53 + 1 parses to the same f64)
        assert_eq!(Json::Num(9_007_199_254_740_992.0).as_exact_usize(),
                   None);
        assert_eq!(Json::Num(9_007_199_254_740_991.0).as_exact_usize(),
                   Some(9_007_199_254_740_991));
        assert_eq!(Json::Str("8".into()).as_exact_usize(), None);
        // the lossy legacy accessor still truncates
        assert_eq!(Json::Num(8.7).as_usize(), Some(8));
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(
            Json::parse("\"\\u0041\"").unwrap(),
            Json::Str("A".into())
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn whitespace_tolerant() {
        let v = Json::parse(" {\n \"k\" :\t[ ] } ").unwrap();
        assert_eq!(v.get("k").unwrap().as_arr().unwrap().len(), 0);
    }

    #[test]
    fn dump_parse_roundtrip_nested() {
        let v = Json::parse(
            r#"{"a": [1, -2.5, {"b": "x\ny", "c": null}], "d": true,
                "e": "", "f": [[], {}]}"#,
        )
        .unwrap();
        assert_eq!(Json::parse(&v.dump()).unwrap(), v);
    }

    #[test]
    fn dump_escapes_strings() {
        let v = Json::Str("a\"b\\c\nd\te\u{8}f".into());
        let text = v.dump();
        assert_eq!(Json::parse(&text).unwrap(), v);
        assert!(text.contains("\\\"") && text.contains("\\\\"));
        assert!(text.contains("\\n") && text.contains("\\u0008"));
    }

    #[test]
    fn dump_floats_roundtrip_exactly() {
        for x in [0.0f64, -0.0, 0.1, 1.5e-8, 12345678.9, -3.0,
                  f32::MAX as f64, 1.0e21, (0.1f32 + 0.2f32) as f64] {
            let text = Json::Num(x).dump();
            assert!(!text.contains('e') && !text.contains('E'), "{text}");
            match Json::parse(&text).unwrap() {
                Json::Num(y) => assert_eq!(
                    x.to_bits(), y.to_bits(), "{x} -> {text} -> {y}"
                ),
                other => panic!("{other:?}"),
            }
        }
        // f32 widened to f64 survives the cycle bit-for-bit
        for f in [0.1f32, 1e-7, 255.0, -17.125, f32::MIN_POSITIVE] {
            let text = Json::Num(f as f64).dump();
            let back = Json::parse(&text).unwrap().as_f64().unwrap() as f32;
            assert_eq!(f.to_bits(), back.to_bits(), "{f} via {text}");
        }
    }

    #[test]
    fn dump_nonfinite_as_null() {
        assert_eq!(Json::Num(f64::NAN).dump(), "null");
        assert_eq!(Json::Num(f64::INFINITY).dump(), "null");
        assert_eq!(Json::parse(&Json::Num(f64::NAN).dump()).unwrap(),
                   Json::Null);
    }

    #[test]
    fn dump_preserves_object_keys() {
        let v = Json::parse(r#"{"z": 1, "a": 2, "m": 3}"#).unwrap();
        let text = v.dump();
        // BTreeMap ordering makes the output canonical (sorted keys) —
        // the cache relies on this for content addressing
        assert_eq!(text, r#"{"a":2,"m":3,"z":1}"#);
    }
}
