//! Minimal JSON parser (no `serde` offline) — reads `manifest.json`.
//!
//! Supports the full JSON value grammar (objects, arrays, strings with
//! escapes, numbers, bools, null). Error messages carry byte offsets.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with a byte offset into the input.
#[derive(Debug)]
pub struct JsonError {
    pub offset: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Object field lookup that panics with a useful message — manifest
    /// fields are trusted build outputs, so missing keys are bugs.
    pub fn req(&self, key: &str) -> &Json {
        self.get(key)
            .unwrap_or_else(|| panic!("manifest: missing key `{key}`"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Array of numbers → Vec<usize> (shapes).
    pub fn as_shape(&self) -> Option<Vec<usize>> {
        self.as_arr()?
            .iter()
            .map(|v| v.as_usize())
            .collect::<Option<Vec<_>>>()
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { offset: self.i, msg: msg.to_string() }
    }

    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected `{}`", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected `{word}`")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let c = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match c {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(
                                &self.b[self.i..self.i + 4],
                            )
                            .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            s.push(
                                char::from_u32(cp)
                                    .ok_or_else(|| self.err("bad codepoint"))?,
                            );
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let start = self.i;
                    self.i += 1;
                    while self.i < self.b.len()
                        && (self.b[self.i] & 0xC0) == 0x80
                    {
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".into())
        );
    }

    #[test]
    fn nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": false}"#)
            .unwrap();
        assert_eq!(v.req("c").as_bool(), Some(false));
        let arr = v.req("a").as_arr().unwrap();
        assert_eq!(arr[2].req("b").as_str(), Some("x"));
    }

    #[test]
    fn shapes() {
        let v = Json::parse("[8, 64, 96]").unwrap();
        assert_eq!(v.as_shape(), Some(vec![8, 64, 96]));
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(
            Json::parse("\"\\u0041\"").unwrap(),
            Json::Str("A".into())
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn whitespace_tolerant() {
        let v = Json::parse(" {\n \"k\" :\t[ ] } ").unwrap();
        assert_eq!(v.req("k").as_arr().unwrap().len(), 0);
    }
}
