//! Fixed-size thread pool (no `tokio`/`rayon` offline).
//!
//! A plain mpsc work queue with panic isolation; `scope_map` provides the
//! fork-join pattern the coordinator uses for host-side HO searches.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

enum Msg {
    Run(Job),
    Shutdown,
}

/// Fixed worker pool; jobs are `FnOnce() + Send`.
pub struct ThreadPool {
    tx: mpsc::Sender<Msg>,
    workers: Vec<JoinHandle<()>>,
    size: usize,
}

impl ThreadPool {
    /// Spawn `size` workers (at least 1).
    pub fn new(size: usize) -> ThreadPool {
        let size = size.max(1);
        let (tx, rx) = mpsc::channel::<Msg>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..size)
            .map(|i| {
                let rx = Arc::clone(&rx);
                std::thread::Builder::new()
                    .name(format!("tq-worker-{i}"))
                    .spawn(move || loop {
                        // tq-lint: allow(lock-across-blocking): idle
                        // workers intentionally serialize on the
                        // receiver mutex — holding it across `recv` IS
                        // the work queue (one waiter wakes per job)
                        let msg = { crate::util::lock(&rx).recv() };
                        match msg {
                            Ok(Msg::Run(job)) => {
                                // panic isolation: a panicking job must
                                // not take the worker (and with it the
                                // whole pool) down
                                let _ = std::panic::catch_unwind(
                                    std::panic::AssertUnwindSafe(job),
                                );
                            }
                            Ok(Msg::Shutdown) | Err(_) => break,
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { tx, workers, size }
    }

    /// Pool sized to available parallelism.
    pub fn default_size() -> ThreadPool {
        let n = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4);
        ThreadPool::new(n)
    }

    pub fn size(&self) -> usize {
        self.size
    }

    /// Fire-and-forget.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx.send(Msg::Run(Box::new(f))).expect("pool alive");
    }

    /// Map `f` over `items` on the pool, preserving order (fork-join).
    pub fn scope_map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let n = items.len();
        let f = Arc::new(f);
        let (rtx, rrx) = mpsc::channel::<(usize, R)>();
        for (i, item) in items.into_iter().enumerate() {
            let f = Arc::clone(&f);
            let rtx = rtx.clone();
            self.execute(move || {
                let r = f(item);
                let _ = rtx.send((i, r));
            });
        }
        drop(rtx);
        let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
        for _ in 0..n {
            let (i, r) = rrx.recv().expect("worker result");
            out[i] = Some(r);
        }
        out.into_iter().map(|o| o.expect("all slots filled")).collect()
    }
}

/// Borrow-friendly data-parallel map using scoped threads: splits
/// `items` into chunks across available cores. Unlike the pool this can
/// capture non-`'static` references — the HO candidate search uses it to
/// share captured calibration tensors without cloning.
pub fn par_map<T, R, F>(items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(4)
        .min(n);
    let chunk = n.div_ceil(workers);
    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    std::thread::scope(|s| {
        let mut rest: &mut [Option<R>] = &mut out;
        let mut start = 0usize;
        let mut handles = Vec::new();
        while start < n {
            let take = chunk.min(n - start);
            let (head, tail) = rest.split_at_mut(take);
            rest = tail;
            let slice = &items[start..start + take];
            let f = &f;
            handles.push(s.spawn(move || {
                for (slot, item) in head.iter_mut().zip(slice) {
                    *slot = Some(f(item));
                }
            }));
            start += take;
        }
        for h in handles {
            h.join().expect("par_map worker panicked");
        }
    });
    out.into_iter().map(|o| o.expect("filled")).collect()
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        for _ in &self.workers {
            let _ = self.tx.send(Msg::Shutdown);
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        let (tx, rx) = mpsc::channel();
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            let tx = tx.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
                let _ = tx.send(());
            });
        }
        for _ in 0..100 {
            rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn scope_map_preserves_order() {
        let pool = ThreadPool::new(3);
        let out = pool.scope_map((0..50).collect::<Vec<_>>(), |x| x * x);
        assert_eq!(out, (0..50).map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn single_worker_pool_works() {
        let pool = ThreadPool::new(1);
        let out = pool.scope_map(vec![1, 2, 3], |x| x + 1);
        assert_eq!(out, vec![2, 3, 4]);
    }

    #[test]
    fn panicking_job_does_not_kill_the_pool() {
        let pool = ThreadPool::new(1);
        pool.execute(|| panic!("boom"));
        // the lone worker must survive to run subsequent jobs
        let out = pool.scope_map(vec![1, 2, 3], |x| x * 2);
        assert_eq!(out, vec![2, 4, 6]);
    }

    #[test]
    fn drop_joins_workers() {
        let pool = ThreadPool::new(2);
        pool.execute(|| {});
        drop(pool); // must not hang
    }

    #[test]
    fn par_map_matches_serial() {
        let items: Vec<u64> = (0..257).collect();
        let out = par_map(&items, |&x| x * 3 + 1);
        assert_eq!(out, items.iter().map(|&x| x * 3 + 1).collect::<Vec<_>>());
    }

    #[test]
    fn par_map_borrows_environment() {
        let base = vec![10u64, 20, 30];
        let items = vec![0usize, 1, 2];
        let out = par_map(&items, |&i| base[i] + 1);
        assert_eq!(out, vec![11, 21, 31]);
    }

    #[test]
    fn par_map_empty() {
        let out: Vec<u64> = par_map(&[] as &[u64], |&x| x);
        assert!(out.is_empty());
    }
}
