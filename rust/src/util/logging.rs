//! Leveled stderr logger + scoped wall-clock timers.
//!
//! Every line carries a monotonic seconds-since-start timestamp and
//! the emitting module's path, so interleaved worker/reactor output
//! can be ordered and attributed without a debugger:
//!
//! ```text
//! [   1.042s WARN  tq_dit::serve::router] worker 2 exited: ...
//! ```
//!
//! The threshold is a process-global atomic: [`set_level`] for
//! programmatic use, [`set_level_str`] for the `--log-level` CLI /
//! config knob (`debug|info|warn|error`).

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Debug = 0,
    Info = 1,
    Warn = 2,
    Error = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(1);
static START: OnceLock<Instant> = OnceLock::new();

/// Seconds since the first log line (or first explicit call) of this
/// process — monotonic, unaffected by wall-clock steps.
pub fn since_start_secs() -> f64 {
    START.get_or_init(Instant::now).elapsed().as_secs_f64()
}

pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Parse a `--log-level` knob value. Accepts `debug|info|warn|error`
/// (case-insensitive); anything else is reported back to the caller.
pub fn set_level_str(s: &str) -> Result<(), String> {
    let level = match s.to_ascii_lowercase().as_str() {
        "debug" => Level::Debug,
        "info" => Level::Info,
        "warn" | "warning" => Level::Warn,
        "error" => Level::Error,
        other => {
            return Err(format!(
                "unknown log level `{other}` (expected debug|info|warn|error)"
            ));
        }
    };
    set_level(level);
    Ok(())
}

pub fn enabled(level: Level) -> bool {
    level as u8 >= LEVEL.load(Ordering::Relaxed)
}

pub fn log(level: Level, module: &str, msg: &str) {
    if enabled(level) {
        let tag = match level {
            Level::Debug => "DEBUG",
            Level::Info => "INFO ",
            Level::Warn => "WARN ",
            Level::Error => "ERROR",
        };
        eprintln!("[{:>8.3}s {tag} {module}] {msg}", since_start_secs());
    }
}

#[macro_export]
macro_rules! info {
    ($($t:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Info,
            module_path!(),
            &format!($($t)*))
    };
}

#[macro_export]
macro_rules! debug_log {
    ($($t:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Debug,
            module_path!(),
            &format!($($t)*))
    };
}

#[macro_export]
macro_rules! warn_log {
    ($($t:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Warn,
            module_path!(),
            &format!($($t)*))
    };
}

#[macro_export]
macro_rules! error_log {
    ($($t:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Error,
            module_path!(),
            &format!($($t)*))
    };
}

/// Scoped timer: logs elapsed time at `Info` when dropped.
pub struct Timer {
    label: String,
    start: Instant,
}

impl Timer {
    pub fn new(label: &str) -> Timer {
        Timer { label: label.to_string(), start: Instant::now() }
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

impl Drop for Timer {
    fn drop(&mut self) {
        log(
            Level::Info,
            module_path!(),
            &format!("{}: {:.2}s", self.label, self.elapsed_secs()),
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // The threshold is process-global; serialize the tests that poke it.
    static LEVEL_GUARD: std::sync::Mutex<()> = std::sync::Mutex::new(());

    #[test]
    fn level_gating() {
        let _g = crate::util::lock(&LEVEL_GUARD);
        set_level(Level::Warn);
        assert!(!enabled(Level::Info));
        assert!(enabled(Level::Error));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
    }

    #[test]
    fn level_strings_parse() {
        let _g = crate::util::lock(&LEVEL_GUARD);
        for s in ["debug", "INFO", "Warn", "warning", "error"] {
            assert!(set_level_str(s).is_ok(), "{s} should parse");
        }
        assert!(set_level_str("loud").is_err());
        set_level(Level::Info);
    }

    #[test]
    fn monotonic_clock_advances() {
        let a = since_start_secs();
        let b = since_start_secs();
        assert!(b >= a);
    }

    #[test]
    fn timer_measures() {
        let t = Timer::new("test");
        std::thread::sleep(std::time::Duration::from_millis(10));
        assert!(t.elapsed_secs() >= 0.01);
    }
}
