//! Leveled stderr logger + scoped wall-clock timers.

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Debug = 0,
    Info = 1,
    Warn = 2,
    Error = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(1);

pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn enabled(level: Level) -> bool {
    level as u8 >= LEVEL.load(Ordering::Relaxed)
}

pub fn log(level: Level, msg: &str) {
    if enabled(level) {
        let tag = match level {
            Level::Debug => "DEBUG",
            Level::Info => "INFO ",
            Level::Warn => "WARN ",
            Level::Error => "ERROR",
        };
        eprintln!("[{tag}] {msg}");
    }
}

#[macro_export]
macro_rules! info {
    ($($t:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Info, &format!($($t)*))
    };
}

#[macro_export]
macro_rules! debug_log {
    ($($t:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Debug, &format!($($t)*))
    };
}

#[macro_export]
macro_rules! warn_log {
    ($($t:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Warn, &format!($($t)*))
    };
}

/// Scoped timer: logs elapsed time at `Info` when dropped.
pub struct Timer {
    label: String,
    start: Instant,
}

impl Timer {
    pub fn new(label: &str) -> Timer {
        Timer { label: label.to_string(), start: Instant::now() }
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

impl Drop for Timer {
    fn drop(&mut self) {
        log(
            Level::Info,
            &format!("{}: {:.2}s", self.label, self.elapsed_secs()),
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(!enabled(Level::Info));
        assert!(enabled(Level::Error));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
    }

    #[test]
    fn timer_measures() {
        let t = Timer::new("test");
        std::thread::sleep(std::time::Duration::from_millis(10));
        assert!(t.elapsed_secs() >= 0.01);
    }
}
