//! Mini property-testing framework (no `proptest` offline).
//!
//! `Gen` wraps the deterministic [`Rng`](super::rng::Rng); properties run
//! for N cases and failures report the seed + a greedy shrink over a
//! caller-provided shrink function. Used by the coordinator invariant
//! tests (routing, batching, quant packing).

use super::rng::Rng;

/// Case generator handle passed into properties.
pub struct Gen<'a> {
    pub rng: &'a mut Rng,
}

impl<'a> Gen<'a> {
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.rng.below(hi - lo + 1)
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.uniform_range(lo, hi)
    }

    pub fn f32_normal(&mut self) -> f32 {
        self.rng.normal() as f32
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    pub fn vec_f32(&mut self, len: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..len).map(|_| self.f32_in(lo, hi)).collect()
    }

    pub fn vec_normal(&mut self, len: usize) -> Vec<f32> {
        self.rng.normal_vec(len)
    }

    pub fn choose<'b, T>(&mut self, items: &'b [T]) -> &'b T {
        &items[self.rng.below(items.len())]
    }
}

/// Outcome of a property check over one generated case.
pub type PropResult = Result<(), String>;

/// Run `prop` for `cases` random cases; panic with seed on failure.
pub fn check<F>(name: &str, cases: usize, mut prop: F)
where
    F: FnMut(&mut Gen) -> PropResult,
{
    let base_seed = 0xC0FFEE_u64;
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case as u64);
        let mut rng = Rng::new(seed);
        let mut g = Gen { rng: &mut rng };
        if let Err(msg) = prop(&mut g) {
            panic!(
                "property `{name}` failed on case {case} (seed {seed:#x}): {msg}"
            );
        }
    }
}

/// Greedy shrink helper: repeatedly applies `shrink` while `fails` holds.
pub fn shrink_to_minimal<T, S, P>(mut value: T, shrink: S, fails: P) -> T
where
    S: Fn(&T) -> Vec<T>,
    P: Fn(&T) -> bool,
{
    loop {
        let mut advanced = false;
        for cand in shrink(&value) {
            if fails(&cand) {
                value = cand;
                advanced = true;
                break;
            }
        }
        if !advanced {
            return value;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check("adds", 50, |g| {
            count += 1;
            let a = g.usize_in(0, 100);
            let b = g.usize_in(0, 100);
            if a + b >= a {
                Ok(())
            } else {
                Err("overflow".into())
            }
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property `always_fails` failed")]
    fn failing_property_panics_with_seed() {
        check("always_fails", 5, |_| Err("nope".into()));
    }

    #[test]
    fn shrink_finds_minimal() {
        // fails for any n >= 13; shrink by decrement.
        let min = shrink_to_minimal(
            100usize,
            |&n| if n > 0 { vec![n - 1] } else { vec![] },
            |&n| n >= 13,
        );
        assert_eq!(min, 13);
    }

    #[test]
    fn gen_ranges() {
        let mut rng = Rng::new(1);
        let mut g = Gen { rng: &mut rng };
        for _ in 0..1000 {
            let v = g.usize_in(3, 9);
            assert!((3..=9).contains(&v));
            let f = g.f32_in(-1.0, 1.0);
            assert!((-1.0..1.0).contains(&f));
        }
    }
}
