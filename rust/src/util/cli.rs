//! Tiny CLI argument parser (no `clap` offline).
//!
//! Grammar: `tq-dit <subcommand> [--flag] [--key value]... [positional]...`
//! Flags may be written `--key value` or `--key=value`. Typed accessors
//! return `Result` with the offending key/value in the message —
//! malformed input is a user error, never a panic.

use std::collections::BTreeMap;

use anyhow::{Context, Result};

/// Parsed command line: subcommand + options + positionals.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub options: BTreeMap<String, String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (after argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut out = Args::default();
        let mut iter = argv.into_iter().peekable();
        while let Some(arg) = iter.next() {
            if let Some(stripped) = arg.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    out.options.insert(stripped.to_string(), v);
                } else {
                    // bare flag
                    out.options.insert(stripped.to_string(), "true".into());
                }
            } else if out.subcommand.is_none() && out.positional.is_empty() {
                out.subcommand = Some(arg);
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    /// Parse the real process args.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn flag(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    pub fn usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| {
                format!("--{key} expects an integer, got `{v}`")
            }),
        }
    }

    pub fn u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| {
                format!("--{key} expects an integer, got `{v}`")
            }),
        }
    }

    pub fn f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| {
                format!("--{key} expects a number, got `{v}`")
            }),
        }
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p(args: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string()))
    }

    #[test]
    fn subcommand_and_options() {
        let a = p(&["table", "--t", "250", "--bits=8", "extra"]);
        assert_eq!(a.subcommand.as_deref(), Some("table"));
        assert_eq!(a.usize("t", 0).unwrap(), 250);
        assert_eq!(a.usize("bits", 0).unwrap(), 8);
        assert_eq!(a.positional, vec!["extra".to_string()]);
    }

    #[test]
    fn bare_flags() {
        let a = p(&["run", "--verbose", "--n", "4"]);
        assert!(a.flag("verbose"));
        assert_eq!(a.usize("n", 0).unwrap(), 4);
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn defaults() {
        let a = p(&["x"]);
        assert_eq!(a.usize("missing", 7).unwrap(), 7);
        assert_eq!(a.f64("missing", 1.5).unwrap(), 1.5);
        assert_eq!(a.str_or("missing", "d"), "d");
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = p(&["cmd", "--a", "--b", "2"]);
        assert!(a.flag("a"));
        assert_eq!(a.usize("b", 0).unwrap(), 2);
    }

    #[test]
    fn calib_cache_flags_parse() {
        // `--calib-cache DIR` takes a value; `--no-calib-cache` is a
        // bare flag — both flow through the config overlay unchanged
        let a = p(&["serve", "--calib-cache", "/tmp/cc",
                    "--no-calib-cache"]);
        assert_eq!(a.get("calib-cache"), Some("/tmp/cc"));
        assert!(a.flag("no-calib-cache"));
        let a = p(&["serve", "--calib-cache=.cache/calib"]);
        assert_eq!(a.get("calib-cache"), Some(".cache/calib"));
        assert!(!a.flag("no-calib-cache"));
    }

    #[test]
    fn malformed_values_error_with_key_and_value() {
        let a = p(&["x", "--n", "abc", "--rate", "fast"]);
        let e = a.usize("n", 0).unwrap_err().to_string();
        assert!(e.contains("--n") && e.contains("abc"), "{e}");
        let e = a.u64("n", 0).unwrap_err().to_string();
        assert!(e.contains("--n"), "{e}");
        let e = a.f64("rate", 0.0).unwrap_err().to_string();
        assert!(e.contains("--rate") && e.contains("fast"), "{e}");
    }
}
