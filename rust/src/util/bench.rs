//! Micro-benchmark harness (no `criterion` offline).
//!
//! Warmup + timed iterations with mean / p50 / p95 / min, printed in a
//! stable machine-grepable format. `cargo bench` targets use
//! `harness = false` and drive this directly; the same harness times the
//! end-to-end table reproductions.

use std::time::Instant;

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
    pub min_s: f64,
}

impl BenchResult {
    pub fn report(&self) {
        println!(
            "bench {:<40} iters {:>4}  mean {:>10}  p50 {:>10}  p95 {:>10}  min {:>10}",
            self.name,
            self.iters,
            fmt_dur(self.mean_s),
            fmt_dur(self.p50_s),
            fmt_dur(self.p95_s),
            fmt_dur(self.min_s),
        );
    }

    /// Throughput helper: items per second at the mean.
    pub fn per_sec(&self, items: usize) -> f64 {
        items as f64 / self.mean_s
    }
}

/// p-th percentile (p in 0..=1) of an ascending-sorted slice; 0.0 when
/// empty. Shared by the bench harness and the serve-layer latency stats.
pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() - 1) as f64 * p.clamp(0.0, 1.0)).round();
    sorted[(idx as usize).min(sorted.len() - 1)]
}

pub fn fmt_dur(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.3}s", s)
    }
}

/// Benchmark runner with warmup and configurable iteration budget.
pub struct Bench {
    pub warmup: usize,
    pub iters: usize,
    /// Hard wall-clock budget; iterations stop early past this.
    pub max_total_s: f64,
}

impl Default for Bench {
    fn default() -> Self {
        Bench { warmup: 2, iters: 10, max_total_s: 60.0 }
    }
}

impl Bench {
    pub fn quick() -> Bench {
        Bench { warmup: 1, iters: 5, max_total_s: 30.0 }
    }

    /// Time `f` and report. `f` should do one logical unit of work.
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> BenchResult {
        for _ in 0..self.warmup {
            f();
        }
        let mut samples = Vec::with_capacity(self.iters);
        let start = Instant::now();
        for _ in 0..self.iters {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64());
            if start.elapsed().as_secs_f64() > self.max_total_s {
                break;
            }
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let res = BenchResult {
            name: name.to_string(),
            iters: n,
            mean_s: mean,
            p50_s: percentile(&samples, 0.50),
            p95_s: percentile(&samples, 0.95),
            min_s: samples[0],
        };
        res.report();
        res
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_picks_expected_samples() {
        assert_eq!(percentile(&[], 0.5), 0.0);
        assert_eq!(percentile(&[3.0], 0.95), 3.0);
        let v: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 1.0), 100.0);
        assert!((percentile(&v, 0.5) - 50.0).abs() <= 1.0);
        assert!((percentile(&v, 0.95) - 95.0).abs() <= 1.0);
    }

    #[test]
    fn runs_expected_iterations() {
        let mut count = 0usize;
        let b = Bench { warmup: 2, iters: 5, max_total_s: 60.0 };
        let r = b.run("noop", || count += 1);
        assert_eq!(count, 7); // warmup + timed
        assert_eq!(r.iters, 5);
        assert!(r.mean_s >= 0.0);
    }

    #[test]
    fn respects_time_budget() {
        let b = Bench { warmup: 0, iters: 1000, max_total_s: 0.05 };
        let r = b.run("sleepy", || {
            std::thread::sleep(std::time::Duration::from_millis(10))
        });
        assert!(r.iters < 1000);
    }

    #[test]
    fn percentiles_ordered() {
        let b = Bench { warmup: 0, iters: 20, max_total_s: 60.0 };
        let r = b.run("noop", || {});
        assert!(r.min_s <= r.p50_s && r.p50_s <= r.p95_s);
    }

    #[test]
    fn duration_formatting() {
        assert!(fmt_dur(2e-9).ends_with("ns"));
        assert!(fmt_dur(2e-6).ends_with("µs"));
        assert!(fmt_dur(2e-3).ends_with("ms"));
        assert!(fmt_dur(2.0).ends_with('s'));
    }
}
