//! From-scratch substrates.
//!
//! The offline vendored registry only provides the `xla` crate's own
//! dependency closure, so the usual ecosystem crates (rand, serde, clap,
//! tokio, criterion, proptest) are unavailable — each gets a small,
//! well-tested replacement here (see DESIGN.md §1, substitution table).

pub mod bench;
pub mod check;
pub mod cli;
pub mod config;
pub mod json;
pub mod logging;
pub mod meminfo;
pub mod rng;
pub mod threadpool;

use std::sync::{Mutex, MutexGuard};

/// Non-poisoning lock, the one way the whole codebase takes a
/// `std::sync::Mutex`: a panicking holder must not take every later
/// accessor down with a `PoisonError` — the guarded state here is
/// queues and counters that stay consistent statement-to-statement,
/// and the serve stack already isolates panics per worker/job. The
/// `non-poisoning-lock` lint rule (see [`crate::analysis`]) keeps
/// call sites on this helper instead of `.lock().unwrap()`.
pub fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

#[cfg(test)]
mod tests {
    use std::sync::{Arc, Mutex};

    #[test]
    fn lock_recovers_from_poisoning() {
        let m = Arc::new(Mutex::new(7usize));
        let m2 = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison the mutex");
        })
        .join();
        assert!(m.lock().is_err(), "mutex should be poisoned");
        assert_eq!(*super::lock(&m), 7);
    }
}
