//! From-scratch substrates.
//!
//! The offline vendored registry only provides the `xla` crate's own
//! dependency closure, so the usual ecosystem crates (rand, serde, clap,
//! tokio, criterion, proptest) are unavailable — each gets a small,
//! well-tested replacement here (see DESIGN.md §1, substitution table).

pub mod bench;
pub mod check;
pub mod cli;
pub mod config;
pub mod json;
pub mod logging;
pub mod meminfo;
pub mod rng;
pub mod threadpool;
