//! `tq-dit` — the leader binary: every experiment of the paper behind
//! one CLI, driving the AOT artifacts through the PJRT runtime.
//!
//! Subcommands:
//!   table          Table I/II rows (FP + any set of calibrators)
//!   ablation       Table III (Baseline / +HO / +MRQ / +TGQ)
//!   efficiency     Table IV (calibration time + memory vs PTQ4DiT)
//!   distributions  Fig. 2/3 CSVs (activation pathologies)
//!   grid           Fig. 6 sample grids (PPM)
//!   sample         generate images with one method, write PPMs
//!   serve          sharded generation service demo (in-process, or a
//!                  cluster frontend with --shards)
//!   node           expose the generation service as a shard node
//!                  (`--listen ADDR`) for a cluster frontend
//!   stats          artifact/manifest inventory + exec stats
//!   lint           static analysis over the repo's own Rust sources
//!                  (concurrency invariants; nonzero exit on findings)
//!
//! Common flags: --artifacts DIR --wbits K --abits K --timesteps T
//!   --groups G --calib-per-group N --rounds R --candidates C
//!   --eval-images N --seed S --ho BOOL --mrq BOOL --tgq BOOL
//!   --calib-cache DIR --no-calib-cache
//!   --reuse-delta X (sampler step-reuse threshold)
//!   --batch-ladder A,B,C --linger-ms N (serve batch policy)
//!   --shards A,B --heartbeat-ms N --node-timeout-ms N
//!   --control-plane BOOL --readmit-pongs K --reconnect-ms N (cluster)
//!   --reactor BOOL --max-conns N (serve/node transport)
//!   --trace BOOL --trace-json PATH (request-scoped tracing)
//!   --metrics-addr HOST:PORT (node: Prometheus endpoint)
//!   --log-level LVL (stderr threshold: debug|info|warn|error)
//!   --config FILE (TOML-subset, overridden by CLI flags)

use std::time::Duration;

use anyhow::{bail, Context, Result};

use tq_dit::coordinator::pipeline::{Method, Pipeline};
use tq_dit::coordinator::QuantConfig;
use tq_dit::metrics::images::{write_grid_ppm, write_ppm};
use tq_dit::serve::net::proto::stats_to_json;
use tq_dit::serve::{
    Cluster, ClusterOpts, Dispatch, GenRequest, GenServer, NodeOpts,
    NodeServer, ServerStats,
};
use tq_dit::util::cli::Args;
use tq_dit::util::config::RunConfig;
use tq_dit::util::logging;
use tq_dit::util::rng::Rng;

fn main() -> Result<()> {
    let mut argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = if argv.first().map(|s| !s.starts_with("--")).unwrap_or(false) {
        argv.remove(0)
    } else {
        "help".to_string()
    };
    let args = Args::parse(argv);
    let cfg = RunConfig::from_args(&args)?;
    // validate() vetted the level string; --verbose is a shorthand
    // that outranks it
    let _ = logging::set_level_str(&cfg.log_level);
    if args.flag("verbose") {
        logging::set_level(logging::Level::Debug);
    }
    if cfg.trace {
        tq_dit::obs::trace::enable(tq_dit::obs::trace::DEFAULT_CAPACITY);
    }

    match cmd.as_str() {
        "table" => cmd_table(cfg, &args),
        "ablation" => cmd_ablation(cfg),
        "efficiency" => cmd_efficiency(cfg),
        "distributions" => cmd_distributions(cfg, &args),
        "grid" => cmd_grid(cfg, &args),
        "sample" => cmd_sample(cfg, &args),
        "serve" => cmd_serve(cfg, &args),
        "node" => cmd_node(cfg, &args),
        "report" => cmd_report(cfg, &args),
        "stats" => cmd_stats(cfg),
        "lint" => cmd_lint(&args),
        "help" | "--help" | "-h" => {
            print!("{}", HELP);
            Ok(())
        }
        other => bail!("unknown subcommand `{other}` (try `tq-dit help`)"),
    }
}

const HELP: &str = "\
tq-dit — Time-Aware Quantization for Diffusion Transformers

USAGE: tq-dit <subcommand> [--flags]

SUBCOMMANDS
  table          Table I/II rows (use --methods a,b,c and --timesteps)
  ablation       Table III ablation at the configured bit-width
  efficiency     Table IV calibration-cost comparison
  distributions  Fig. 2/3 activation-distribution CSVs (--out-dir)
  grid           Fig. 6 sample grids as PPM (--out-dir, --rows, --cols)
  sample         generate images with --method, write PPMs (--out-dir)
  serve          sharded generation service demo (--requests, --workers;
                 with --shards A,B it is a cluster frontend instead)
  node           serve as a shard node for a cluster frontend
                 (--listen ADDR, --workers, --run-secs N; 0 = forever)
  report         per-layer quantization-error attribution (--method)
  stats          manifest inventory
  lint           whole-program static analysis over the repo's own Rust
                 sources ([PATHS...], default rust/src; --json PATH
                 writes a machine-readable report, --graph-json PATH
                 dumps the inferred call graph, --pragmas lists every
                 suppression with its reason, --ratchet FILE enforces
                 the pragma-count baseline; exits nonzero on findings)

FLAGS (all subcommands)
  --artifacts DIR       AOT artifact directory  [artifacts]
  --wbits K --abits K   weight/activation bits  [8/8]
  --timesteps T         sampler steps           [250]
  --groups G            TGQ time groups         [10]
  --calib-per-group N   calib samples per group [32]
  --rounds R            alternating HO rounds   [3]
  --candidates C        scale candidates per 1-D search [80]
  --eval-images N       images per FID/IS cell  [256]
  --ho/--mrq/--tgq B    ablation toggles        [true]
  --calib-cache DIR     persistent calibration cache (serve/sample/
                        report skip recalibration)   [calib-cache]
  --no-calib-cache      disable calibration-cache load and store
  --batch-ladder A,B,C  serve: restrict workers to these lowered batch
                        rungs                   [all manifest rungs]
  --linger-ms N         serve: deadline before a partial batch rung
                        dispatches padded       [0 = immediately]
  --shards A,B          serve: dispatch across these shard nodes
                        instead of in-process workers
  --heartbeat-ms N      cluster: shard heartbeat cadence      [500]
  --node-timeout-ms N   cluster: declare a shard dead after this long
                        without a heartbeat (re-queues its work) [2500]
  --control-plane BOOL  cluster: dedicated per-shard control connection
                        for ping/pong/stats, so liveness never queues
                        behind response frames          [true]
  --readmit-pongs K     cluster: consecutive pongs before a recovered
                        shard re-enters placement       [3]
  --reconnect-ms N      cluster: how often dead shards are re-dialed
                        for re-admission                [1000]
  --reuse-delta X       sampler: step-reuse threshold — TGQ groups whose
                        calibration drift is below X share one forward
                        pass per reuse run; 0 disables reuse and is
                        byte-identical to the plain sampler   [0.05]
  --reactor BOOL        serve/node: event-driven transport — one poll(2)
                        reactor thread owns every connection instead of
                        one handler thread each; both transports speak
                        the same wire protocol; `--reactor false` falls
                        back to one handler thread per connection [true]
  --max-conns N         node: accepted-connection cap in reactor mode
                        (refused at accept past the cap)     [4096]
  --stats-json PATH     serve/node: dump final ServerStats (local or
                        cluster-aggregated) as canonical JSON on
                        shutdown (node: needs a bounded --run-secs)
  --trace BOOL          request-scoped tracing: spans for queue/linger/
                        rung-pick/generate/encode (and, on a cluster
                        frontend, the per-shard dispatch hop — nodes
                        ship spans home on the response)      [false]
  --trace-json PATH     write collected spans as Chrome trace JSON on
                        shutdown (chrome://tracing / Perfetto);
                        implies --trace true
  --metrics-addr A:P    node (reactor mode): serve Prometheus text
                        exposition at GET /metrics on this address
  --log-level LVL       stderr log threshold, debug|info|warn|error
                        (--verbose is shorthand for debug)     [info]
  --seed S --verbose --config FILE
";

fn cmd_table(cfg: RunConfig, args: &Args) -> Result<()> {
    let methods: Vec<Method> = args
        .str_or("methods", "q-diffusion,ptqd,ptq4dit,tq-dit")
        .split(',')
        .filter_map(Method::parse)
        .collect();
    println!("== T={} W{}A{} ({} eval images) ==", cfg.timesteps, cfg.wbits,
             cfg.abits, cfg.eval_images);
    println!("{:<22} {:>9} {:>9} {:>8} {:>9}", "method", "FID", "sFID",
             "IS", "calib(s)");
    let pipe = Pipeline::new(cfg.clone())?;
    let fp = QuantConfig::fp(pipe.groups.clone());
    let r = pipe.evaluate(&fp, cfg.eval_images, cfg.seed ^ 0xe7a1)?;
    println!("{:<22} {:>9.3} {:>9.3} {:>8.3} {:>9}", "FP (32/32)", r.fid,
             r.sfid, r.is_score, "-");
    for method in methods {
        let mut rng = Rng::new(cfg.seed ^ 0x5eed);
        let (qc, cost) = pipe.calibrate(method, &mut rng)?;
        let row = pipe.evaluate(&qc, cfg.eval_images, cfg.seed ^ 0xe7a1)?;
        println!("{:<22} {:>9.3} {:>9.3} {:>8.3} {:>9.1}",
                 format!("{} ({}/{})", method.name(), cfg.wbits, cfg.abits),
                 row.fid, row.sfid, row.is_score, cost.wall_s);
    }
    Ok(())
}

fn cmd_ablation(cfg: RunConfig) -> Result<()> {
    println!("== ablation (W{}A{}, T={}) ==", cfg.wbits, cfg.abits,
             cfg.timesteps);
    println!("{:<24} {:>9} {:>9} {:>8}", "config", "FID", "sFID", "IS");
    let mut pipe = Pipeline::new(cfg.clone())?;
    let fp = QuantConfig::fp(pipe.groups.clone());
    let r = pipe.evaluate(&fp, cfg.eval_images, cfg.seed ^ 0xe7a1)?;
    println!("{:<24} {:>9.3} {:>9.3} {:>8.3}", "FP", r.fid, r.sfid,
             r.is_score);
    for (label, ho, mrq, tgq) in [
        ("Baseline", false, false, false),
        ("+ HO", true, false, false),
        ("+ HO + MRQ", true, true, false),
        ("+ HO + MRQ + TGQ", true, true, true),
    ] {
        pipe.cfg.use_ho = ho;
        pipe.cfg.use_mrq = mrq;
        pipe.cfg.use_tgq = tgq;
        let mut rng = Rng::new(cfg.seed ^ 0x5eed);
        let (qc, _) = pipe.calibrate(Method::TqDit, &mut rng)?;
        let row = pipe.evaluate(&qc, cfg.eval_images, cfg.seed ^ 0xe7a1)?;
        println!("{:<24} {:>9.3} {:>9.3} {:>8.3}", label, row.fid, row.sfid,
                 row.is_score);
    }
    Ok(())
}

fn cmd_efficiency(cfg: RunConfig) -> Result<()> {
    let pipe = Pipeline::new(cfg.clone())?;
    for method in [Method::Ptq4Dit, Method::TqDit] {
        let mut rng = Rng::new(cfg.seed ^ 0x5eed);
        let (_, cost) = pipe.calibrate(method, &mut rng)?;
        cost.print(method.name());
    }
    Ok(())
}

fn cmd_distributions(cfg: RunConfig, args: &Args) -> Result<()> {
    use std::io::Write;
    let out_dir = args.str_or("out-dir", ".").to_string();
    let pipe = Pipeline::new(cfg.clone())?;
    let mut rng = Rng::new(cfg.seed);
    let (_, ev) = pipe.grouped_evidence(&mut rng)?;
    for (name, hist) in [("fig2a_softmax_hist.csv", &ev.softmax_hist),
                         ("fig2b_gelu_hist.csv", &ev.gelu_hist)] {
        let p = std::path::Path::new(&out_dir).join(name);
        let mut f = std::fs::File::create(&p)?;
        writeln!(f, "center,density")?;
        for (c, d) in hist.densities() {
            writeln!(f, "{c},{d}")?;
        }
        println!("wrote {}", p.display());
    }
    let p = std::path::Path::new(&out_dir).join("fig3_softmax_max_by_t.csv");
    let mut rows = ev.softmax_max_by_t.clone();
    rows.sort_by_key(|r| r.0);
    let mut f = std::fs::File::create(&p)?;
    writeln!(f, "timestep,max_softmax")?;
    for (t, m) in rows {
        writeln!(f, "{t},{m}")?;
    }
    println!("wrote {}", p.display());
    Ok(())
}

fn cmd_grid(cfg: RunConfig, args: &Args) -> Result<()> {
    let out_dir = args.str_or("out-dir", ".").to_string();
    let rows = args.usize("rows", 4)?;
    let cols = args.usize("cols", 8)?;
    let pipe = Pipeline::new(cfg.clone())?;
    let m = pipe.rt.manifest.model.clone();
    let fp = QuantConfig::fp(pipe.groups.clone());
    let imgs = pipe.sample_grid(&fp, rows * cols, cfg.seed ^ 0x9b1d)?;
    let p = std::path::Path::new(&out_dir).join("fig6_fp.ppm");
    write_grid_ppm(&p, &imgs, m.img_size, m.img_size, rows, cols)?;
    println!("wrote {}", p.display());
    for method in [Method::Ptq4Dit, Method::TqDit] {
        let mut rng = Rng::new(cfg.seed ^ 0x5eed);
        let (qc, _) = pipe.calibrate(method, &mut rng)?;
        let imgs = pipe.sample_grid(&qc, rows * cols, cfg.seed ^ 0x9b1d)?;
        let p = std::path::Path::new(&out_dir).join(format!(
            "fig6_{}_w{}a{}.ppm", method.name(), cfg.wbits, cfg.abits));
        write_grid_ppm(&p, &imgs, m.img_size, m.img_size, rows, cols)?;
        println!("wrote {}", p.display());
    }
    Ok(())
}

fn cmd_sample(cfg: RunConfig, args: &Args) -> Result<()> {
    let out_dir = args.str_or("out-dir", ".").to_string();
    let n = args.usize("n", 8)?;
    let method = Method::parse(args.str_or("method", "tq-dit"))
        .ok_or_else(|| anyhow::anyhow!("unknown --method"))?;
    let pipe = Pipeline::new(cfg.clone())?;
    let m = pipe.rt.manifest.model.clone();
    let qc = if method == Method::Fp {
        QuantConfig::fp(pipe.groups.clone())
    } else {
        pipe.calibrate_cached(method)?.0
    };
    let imgs = pipe.sample_grid(&qc, n, cfg.seed ^ 0x9b1d)?;
    let il = m.img_size * m.img_size * m.channels;
    for i in 0..n {
        let p = std::path::Path::new(&out_dir)
            .join(format!("sample_{}_{i:03}.ppm", method.name()));
        write_ppm(&p, &imgs[i * il..(i + 1) * il], m.img_size, m.img_size)?;
    }
    println!("wrote {n} samples to {out_dir}");
    Ok(())
}

/// `--stats-json PATH`: dump the final stats via the canonical
/// serializer so benches and operators can diff runs.
fn write_stats_json(path: Option<&str>, stats: &ServerStats)
                    -> Result<()> {
    let Some(path) = path else { return Ok(()) };
    std::fs::write(path, stats_to_json(stats).dump())
        .with_context(|| format!("writing stats json {path}"))?;
    println!("wrote stats to {path}");
    Ok(())
}

/// `--trace-json PATH`: export the span ring as Chrome trace JSON
/// (load in `chrome://tracing` or Perfetto) after shutdown, once every
/// in-flight request has landed its spans.
fn write_trace_json(path: Option<&str>) -> Result<()> {
    let Some(path) = path else { return Ok(()) };
    let n = tq_dit::obs::trace::write_chrome_json(
        std::path::Path::new(path))
        .with_context(|| format!("writing trace json {path}"))?;
    println!("wrote {n} span(s) to {path}");
    Ok(())
}

fn cmd_serve(cfg: RunConfig, args: &Args) -> Result<()> {
    let n_req = args.usize("requests", 6)?;
    let workers = args.usize("workers", 1)?;
    let stats_json = args.get("stats-json").map(str::to_string);
    let trace_json = cfg.trace_json.clone();
    let method = Method::parse(args.str_or("method", "tq-dit"))
        .ok_or_else(|| anyhow::anyhow!("unknown --method"))?;
    // one driver for both topologies: the in-process server and the
    // cluster frontend expose the same Dispatch surface
    let server: Box<dyn Dispatch> = match cfg.shards.clone() {
        Some(shards) => {
            println!("serving via {} shard node(s): {}", shards.len(),
                     shards.join(", "));
            Box::new(Cluster::connect(
                &shards, ClusterOpts::from_run_config(&cfg))?)
        }
        None => Box::new(GenServer::with_workers(cfg, method, workers)),
    };
    let mut handles = Vec::new();
    for i in 0..n_req {
        let req = GenRequest { class: (i % 8) as i32, n: 3 + (i * 5) % 11 };
        handles.push((i, server.submit(req)?));
    }
    for (i, (id, rx)) in handles {
        match rx.recv()? {
            Ok(resp) => println!("req {i} (id {id}): {} px in {:.2}s",
                                 resp.images.len(), resp.latency_s),
            Err(e) => println!("req {i} (id {id}): failed: {e}"),
        }
    }
    let stats = server.shutdown();
    stats.print();
    write_stats_json(stats_json.as_deref(), &stats)?;
    write_trace_json(trace_json.as_deref())?;
    Ok(())
}

fn cmd_node(cfg: RunConfig, args: &Args) -> Result<()> {
    let listen = args.str_or("listen", "127.0.0.1:7070").to_string();
    let workers = args.usize("workers", 1)?;
    let run_secs = args.u64("run-secs", 0)?;
    let stats_json = args.get("stats-json").map(str::to_string);
    let trace_json = cfg.trace_json.clone();
    let method = Method::parse(args.str_or("method", "tq-dit"))
        .ok_or_else(|| anyhow::anyhow!("unknown --method"))?;
    let metrics_addr = match cfg.metrics_addr.as_deref() {
        None => None,
        Some(a) => {
            use std::net::ToSocketAddrs;
            Some(
                a.to_socket_addrs()
                    .with_context(|| {
                        format!("resolving --metrics-addr {a}")
                    })?
                    .next()
                    .ok_or_else(|| anyhow::anyhow!(
                        "--metrics-addr {a}: no resolvable address"))?,
            )
        }
    };
    if metrics_addr.is_some() && !cfg.reactor {
        eprintln!("warning: --metrics-addr needs the reactor transport \
                   (--reactor true); no metrics endpoint will be bound");
    }
    let node_opts = NodeOpts {
        reactor: cfg.reactor,
        max_conns: cfg.max_conns,
        metrics_addr,
        ..NodeOpts::default()
    };
    let server = GenServer::with_workers(cfg, method, workers);
    let node = NodeServer::start(Box::new(server), &listen, node_opts)?;
    println!("shard node listening on {} ({} worker(s), method {}, {} \
              transport)",
             node.addr(), workers, method.name(),
             if node_opts.reactor { "reactor" } else { "threaded" });
    if let Some(m) = node.metrics_addr() {
        println!("metrics exposition on http://{m}/metrics");
    }
    if run_secs == 0 {
        if stats_json.is_some() {
            // no signal handling offline: an unbounded run ends by
            // being killed, so the post-shutdown dump never executes
            eprintln!("warning: --stats-json requires a bounded run \
                       (--run-secs N); no stats will be written");
        }
        println!("serving until killed (--run-secs N bounds the run)");
        loop {
            std::thread::sleep(Duration::from_secs(3600));
        }
    }
    std::thread::sleep(Duration::from_secs(run_secs));
    let stats = node.shutdown();
    stats.print();
    write_stats_json(stats_json.as_deref(), &stats)?;
    Ok(())
}

fn cmd_report(cfg: RunConfig, args: &Args) -> Result<()> {
    let method = Method::parse(args.str_or("method", "tq-dit"))
        .ok_or_else(|| anyhow::anyhow!("unknown --method"))?;
    let pipe = Pipeline::new(cfg.clone())?;
    let (qc, _, _) = pipe.calibrate_cached(method)?;
    // fresh evidence (held-out seed) so the report is not scored on the
    // same tuples the search optimized
    let mut rng2 = Rng::new(cfg.seed ^ 0x4e1d);
    let (_, ev) = {
        let mut p2 = Pipeline::new(cfg.clone())?;
        p2.cfg.calib_per_group = (cfg.calib_per_group / 2).max(2);
        p2.grouped_evidence(&mut rng2)?
    };
    let reps = tq_dit::coordinator::report::error_report(
        &pipe.rt.manifest, &pipe.weights, &ev, &qc);
    tq_dit::coordinator::report::print_report(
        reps, &format!("{} W{}A{}", method.name(), cfg.wbits, cfg.abits));
    Ok(())
}

/// `tq-dit lint [--json PATH] [--graph-json PATH] [--pragmas]
/// [--ratchet FILE] [PATHS...]` — run the crate's own whole-program
/// static analysis (see `tq_dit::analysis`) over the given
/// files/directories, defaulting to the Rust source tree. Exits
/// nonzero on any finding so CI can gate on it. `--json` writes the
/// findings report, `--graph-json` dumps the inferred call graph,
/// `--pragmas` lists every suppression with its reason, and
/// `--ratchet FILE` enforces the pragma-count baseline (fails if the
/// count grew; rewrites the file if it shrank).
fn cmd_lint(args: &Args) -> Result<()> {
    let roots: Vec<std::path::PathBuf> = if args.positional.is_empty() {
        // work from either the repo root or rust/
        let rs = std::path::PathBuf::from("rust/src");
        vec![if rs.is_dir() { rs } else { "src".into() }]
    } else {
        args.positional.iter().map(Into::into).collect()
    };
    let run = tq_dit::analysis::lint_tree(&roots)
        .with_context(|| format!("linting {roots:?}"))?;
    for f in &run.findings {
        println!("{f}");
    }
    if let Some(path) = args.get("json") {
        let report = tq_dit::analysis::report_json(&run.findings);
        std::fs::write(path, report.dump())
            .with_context(|| format!("writing lint report {path}"))?;
        eprintln!("wrote lint report to {path}");
    }
    if let Some(path) = args.get("graph-json") {
        std::fs::write(path, run.graph.to_json().dump())
            .with_context(|| format!("writing call graph {path}"))?;
        eprintln!("wrote call graph to {path}");
    }
    if args.flag("pragmas") {
        println!("{} pragma(s):", run.pragmas.len());
        for (file, r) in &run.pragmas {
            println!(
                "  {file}:{}: {}({}) — {}",
                r.line,
                if r.filewide { "allow-file" } else { "allow" },
                r.rule,
                r.reason
            );
        }
    }
    let mut ratchet_err = None;
    if let Some(path) = args.get("ratchet") {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading pragma baseline {path}"))?;
        let baseline = tq_dit::analysis::parse_ratchet(&text).ok_or_else(
            || anyhow::anyhow!("{path}: no pragma count found"),
        )?;
        let n = run.pragmas.len();
        if n > baseline {
            eprintln!(
                "pragma ratchet: {n} pragma(s) exceeds baseline {baseline} \
                 — remove one, or justify the new one in review and bump \
                 {path}:"
            );
            for (file, r) in &run.pragmas {
                eprintln!("  {file}:{}: allow({}) — {}", r.line, r.rule, r.reason);
            }
            ratchet_err = Some(format!(
                "pragma count {n} exceeds baseline {baseline}"
            ));
        } else if n < baseline {
            // shrinking is progress: auto-tighten the baseline
            std::fs::write(
                path,
                format!(
                    "# Production `tq-lint` pragma count — the ratchet \
                     floor.\n# `tq-dit lint --ratchet` fails when the live \
                     count exceeds this\n# number and rewrites it downward \
                     when suppressions are removed.\n{n}\n"
                ),
            )
            .with_context(|| format!("tightening pragma baseline {path}"))?;
            eprintln!("pragma ratchet: {n} < baseline {baseline}; tightened {path}");
        } else {
            eprintln!("pragma ratchet: {n} pragma(s), at baseline");
        }
    }
    for (label, ns) in &run.timings {
        eprintln!("  {label:<34} {:>9.2} ms", *ns as f64 / 1e6);
    }
    eprintln!(
        "lint: {} file(s), {} fn(s), {} inferred blocking, {:.1} ms total",
        run.files,
        run.graph.fn_count(),
        run.graph.blocking_count(),
        run.wall_ns as f64 / 1e6
    );
    if let Some(e) = ratchet_err {
        bail!("lint: {e}");
    }
    if run.findings.is_empty() {
        eprintln!("lint: clean");
        Ok(())
    } else {
        bail!("lint: {} finding(s)", run.findings.len());
    }
}

fn cmd_stats(cfg: RunConfig) -> Result<()> {
    let pipe = Pipeline::new(cfg)?;
    let m = &pipe.rt.manifest;
    println!("model: dim={} depth={} heads={} tokens={} classes={}",
             m.model.dim, m.model.depth, m.model.heads, m.model.tokens,
             m.model.num_classes);
    println!("diffusion: T_train={} beta=[{}, {}]", m.diffusion.train_steps,
             m.diffusion.beta_start, m.diffusion.beta_end);
    println!("params: {} tensors, {} elements", m.n_params(),
             pipe.weights.n_elements());
    println!("quant sites: {} ({} qp floats)", m.sites().len(), m.qp_len);
    println!("classifier acc (build time): {:.3}", m.classifier_acc);
    println!("artifacts:");
    for (name, file) in &m.artifacts {
        let size = std::fs::metadata(m.dir.join(file))
            .map(|md| md.len())
            .unwrap_or(0);
        println!("  {name:<18} {file:<26} {:>9}",
                 tq_dit::util::meminfo::fmt_bytes(size));
    }
    Ok(())
}
