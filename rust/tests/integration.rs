//! Integration tests over the real AOT artifacts + PJRT runtime.
//!
//! These exercise the cross-language contract end to end: manifest ↔
//! loader, python-lowered HLO ↔ rust execution, bypass-qparams ↔ FP
//! equivalence, capture ↔ quantize ↔ sampler composition.
//!
//! They require `make artifacts` to have run; each test skips (with a
//! note) when the artifacts are absent so `cargo test` stays green on a
//! fresh checkout.

use std::path::{Path, PathBuf};

use tq_dit::coordinator::calib::CalibSet;
use tq_dit::coordinator::capture::{run_capture, CaptureOpts};
use tq_dit::coordinator::pipeline::{Method, Pipeline};
use tq_dit::coordinator::quantize::{quantize, QuantizeOpts};
use tq_dit::coordinator::QuantConfig;
use tq_dit::data::SynthDataset;
use tq_dit::metrics::Evaluator;
use tq_dit::model::WeightStore;
use tq_dit::quant::QP_STRIDE;
use tq_dit::runtime::Runtime;
use tq_dit::sampler::Sampler;
use tq_dit::sched::{DdpmSchedule, TimeGroups};
use tq_dit::tensor::Tensor;
use tq_dit::util::config::RunConfig;
use tq_dit::util::rng::Rng;

fn artifacts_dir() -> Option<PathBuf> {
    let p = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if p.join("manifest.json").exists() {
        Some(p)
    } else {
        eprintln!("[skip] artifacts not built — run `make artifacts`");
        None
    }
}

macro_rules! require_artifacts {
    () => {
        match artifacts_dir() {
            Some(p) => p,
            None => return,
        }
    };
}

fn small_cfg(dir: &Path) -> RunConfig {
    RunConfig {
        artifacts: dir.to_str().unwrap().to_string(),
        timesteps: 25,
        groups: 5,
        calib_per_group: 4,
        rounds: 1,
        candidates: 16,
        eval_images: 16,
        // isolation: no shared on-disk cache between tests/runs — the
        // cache-specific test below opts in with its own temp dir
        calib_cache: None,
        ..RunConfig::default()
    }
}

#[test]
fn manifest_layout_invariants() {
    let dir = require_artifacts!();
    let rt = Runtime::load(&dir).unwrap();
    let m = &rt.manifest;
    // qp slots: stride-4, contiguous, one per site
    let sites = m.sites();
    assert_eq!(m.qp_len, sites.len() * QP_STRIDE);
    for (i, s) in sites.iter().enumerate() {
        assert_eq!(s.qp_offset, i * QP_STRIDE, "site {}", s.name);
    }
    // every linear layer's weight exists in params
    for l in &m.layers {
        if l.ltype == "linear" {
            assert!(m.params.iter().any(|(n, _)| n == &l.weight),
                    "missing weight {}", l.weight);
        }
    }
    // capture outputs: every site input + every layer grad
    for l in &m.layers {
        assert!(m.capture_index(&format!("{}.grad", l.name)).is_some());
        for s in &l.sites {
            assert!(m.capture_index(&s.name).is_some(), "{}", s.name);
        }
    }
}

#[test]
fn weights_and_metric_weights_load() {
    let dir = require_artifacts!();
    let rt = Runtime::load(&dir).unwrap();
    let ws = WeightStore::load(&rt.manifest).unwrap();
    assert_eq!(ws.tensors.len(), rt.manifest.n_params());
    assert!(ws.n_elements() > 100_000);
    // all finite
    for t in &ws.tensors {
        assert!(t.data.iter().all(|v| v.is_finite()));
    }
    let (fw, cw) = rt.manifest.load_metric_weights().unwrap();
    assert_eq!(fw.len(), rt.manifest.feat_params.len());
    assert_eq!(cw.len(), rt.manifest.clf_params.len());
}

#[test]
fn bypass_qparams_match_fp_forward() {
    let dir = require_artifacts!();
    let rt = Runtime::load(&dir).unwrap();
    let m = rt.manifest.clone();
    let ws = WeightStore::load(&m).unwrap();
    let mut rng = Rng::new(11);
    let b = m.batches.calib;
    let il = m.model.img_size * m.model.img_size * m.model.channels;
    let x = Tensor::new(vec![b, m.model.img_size, m.model.img_size,
                             m.model.channels],
                        rng.normal_vec(b * il));
    let t: Vec<i32> = (0..b).map(|_| rng.below(250) as i32).collect();
    let y: Vec<i32> = (0..b).map(|_| rng.below(8) as i32).collect();

    let wb = rt.upload_all(&ws.tensors).unwrap();
    let xb = rt.upload(&x).unwrap();
    let tb = rt.upload_i32(&t, &[b]).unwrap();
    let yb = rt.upload_i32(&y, &[b]).unwrap();
    let mut fp_in: Vec<&xla::PjRtBuffer> = wb.iter().collect();
    fp_in.extend([&xb, &tb, &yb]);
    let fp = &rt.run_buffers("dit_fp_calib", &fp_in).unwrap()[0];

    let qp = Tensor::new(vec![m.qp_len], vec![0.0; m.qp_len]);
    let qpb = rt.upload(&qp).unwrap();
    let mut q_in: Vec<&xla::PjRtBuffer> = wb.iter().collect();
    q_in.extend([&xb, &tb, &yb, &qpb]);
    let q = &rt.run_buffers("dit_quant_calib", &q_in).unwrap()[0];

    assert_eq!(fp.shape, q.shape);
    assert!(fp.mse(q) < 1e-9, "bypass path diverged: {}", fp.mse(q));
}

#[test]
fn quantized_qparams_perturb_forward() {
    let dir = require_artifacts!();
    let rt = Runtime::load(&dir).unwrap();
    let m = rt.manifest.clone();
    let ws = WeightStore::load(&m).unwrap();
    let mut rng = Rng::new(13);
    let b = m.batches.calib;
    let il = m.model.img_size * m.model.img_size * m.model.channels;
    let x = Tensor::new(vec![b, m.model.img_size, m.model.img_size,
                             m.model.channels],
                        rng.normal_vec(b * il));
    let t = vec![100i32; b];
    let y = vec![1i32; b];
    let wb = rt.upload_all(&ws.tensors).unwrap();
    let xb = rt.upload(&x).unwrap();
    let tb = rt.upload_i32(&t, &[b]).unwrap();
    let yb = rt.upload_i32(&y, &[b]).unwrap();

    // crude uniform 4-bit on every uniform site via min-max defaults
    let mut qp = vec![0.0f32; m.qp_len];
    for s in rt.manifest.sites() {
        if s.kind == tq_dit::runtime::SiteKind::Uniform {
            qp[s.qp_offset] = 0.5;
            qp[s.qp_offset + 1] = 8.0;
            qp[s.qp_offset + 2] = 15.0;
        }
    }
    let qpb = rt.upload(&Tensor::new(vec![m.qp_len], qp)).unwrap();
    let mut q_in: Vec<&xla::PjRtBuffer> = wb.iter().collect();
    q_in.extend([&xb, &tb, &yb, &qpb]);
    let q = &rt.run_buffers("dit_quant_calib", &q_in).unwrap()[0];

    let byp = rt.upload(&Tensor::new(vec![m.qp_len],
                                     vec![0.0; m.qp_len])).unwrap();
    let mut b_in: Vec<&xla::PjRtBuffer> = wb.iter().collect();
    b_in.extend([&xb, &tb, &yb, &byp]);
    let fp = &rt.run_buffers("dit_quant_calib", &b_in).unwrap()[0];
    let mse = fp.mse(q);
    assert!(mse > 1e-6, "4-bit qparams had no effect (mse {mse})");
    assert!(q.data.iter().all(|v| v.is_finite()));
}

#[test]
fn capture_covers_every_layer_and_group() {
    let dir = require_artifacts!();
    let rt = Runtime::load(&dir).unwrap();
    let ws = WeightStore::load(&rt.manifest).unwrap();
    let ds = SynthDataset::new(rt.manifest.model.img_size,
                               rt.manifest.model.channels,
                               rt.manifest.model.num_classes);
    let d = &rt.manifest.diffusion;
    let sched = DdpmSchedule::new(d.train_steps, d.beta_start, d.beta_end,
                                  d.train_steps);
    let tg = TimeGroups::new(d.train_steps, 5);
    let mut rng = Rng::new(3);
    let calib = CalibSet::build(&ds, &sched, &tg, 8, &mut rng).unwrap();
    let ev = run_capture(&rt, &ws, &calib, CaptureOpts::default()).unwrap();

    assert_eq!(ev.layers.len(), rt.manifest.layers.len());
    for l in &rt.manifest.layers {
        let le = ev.layer(&l.name);
        assert_eq!(le.a.len(), 5);
        for g in 0..5 {
            assert!(!le.a[g].is_empty(), "layer {} group {g} empty", l.name);
            assert_eq!(le.a[g].len(), le.fisher[g].len());
            if l.ltype == "matmul" {
                assert_eq!(le.a[g].len(), le.b[g].len());
                // stored pairs must be matmul-compatible
                let (am, bm) = (&le.a[g][0], &le.b[g][0]);
                assert_eq!(am.cols(), bm.shape[0], "layer {}", l.name);
            }
        }
    }
    // Fig. 2/3 side channels populated
    assert!(ev.softmax_hist.count > 1000);
    assert!(ev.gelu_hist.count > 1000);
    assert_eq!(ev.softmax_max_by_t.len(),
               calib.len() * rt.manifest.model.depth);
    // post-softmax values live in [0, 1] — underflow impossible
    assert_eq!(ev.softmax_hist.underflow, 0);
}

#[test]
fn quantize_emits_params_for_every_site() {
    let dir = require_artifacts!();
    let rt = Runtime::load(&dir).unwrap();
    let ws = WeightStore::load(&rt.manifest).unwrap();
    let ds = SynthDataset::new(16, 3, 8);
    let d = &rt.manifest.diffusion;
    let sched = DdpmSchedule::new(d.train_steps, d.beta_start, d.beta_end,
                                  d.train_steps);
    let tg = TimeGroups::new(d.train_steps, 5);
    let mut rng = Rng::new(5);
    let calib = CalibSet::build(&ds, &sched, &tg, 4, &mut rng).unwrap();
    let ev = run_capture(&rt, &ws, &calib, CaptureOpts::default()).unwrap();
    let opts = QuantizeOpts {
        rounds: 1,
        candidates: 12,
        ..QuantizeOpts::default()
    };
    let (qc, cost) = quantize(&rt.manifest, &ws, &ev, &tg, "tq-dit", opts)
        .unwrap();

    // every site got params; every linear weight got a quantizer
    for l in &rt.manifest.layers {
        for s in &l.sites {
            assert!(qc.sites.contains_key(&s.name), "{}", s.name);
        }
        if l.ltype == "linear" {
            assert!(qc.weights.contains_key(&l.weight), "{}", l.weight);
        }
    }
    // TGQ overlays exactly on the tgq sites, with one entry per group
    let tgq_sites: Vec<_> = rt.manifest.sites().iter()
        .filter(|s| s.tgq).map(|s| s.name.clone()).collect();
    assert_eq!(qc.tgq.len(), tgq_sites.len());
    for s in &tgq_sites {
        assert_eq!(qc.tgq[s].len(), 5);
    }
    assert!(cost.evals > 0);

    // packing: every uniform slot has s > 0 (nothing left bypassed)
    let v = qc.qparams_for_group(&rt.manifest, 0);
    for s in rt.manifest.sites() {
        assert!(v[s.qp_offset] > 0.0, "site {} left bypassed", s.name);
    }
}

#[test]
fn sampler_is_deterministic_and_seed_sensitive() {
    let dir = require_artifacts!();
    let cfg = small_cfg(&dir);
    let pipe = Pipeline::new(cfg.clone()).unwrap();
    let fp = QuantConfig::fp(pipe.groups.clone());
    let sampler = Sampler::new(&pipe.rt, &pipe.weights, fp,
                               cfg.timesteps).unwrap();
    let labels = vec![0i32; sampler.batch()];
    let (a, st) = sampler.sample(&labels, &mut Rng::new(42)).unwrap();
    let (b, _) = sampler.sample(&labels, &mut Rng::new(42)).unwrap();
    assert_eq!(a, b, "same seed must reproduce exactly");
    let (c, _) = sampler.sample(&labels, &mut Rng::new(43)).unwrap();
    assert_ne!(a, c, "different seed must differ");
    assert_eq!(st.steps, cfg.timesteps);
    assert_eq!(st.qp_swaps, 0, "FP path packs no qparams");
}

#[test]
fn tgq_sampler_swaps_once_per_group() {
    let dir = require_artifacts!();
    let cfg = small_cfg(&dir);
    let pipe = Pipeline::new(cfg.clone()).unwrap();
    let mut qc = QuantConfig::new("tq-dit", 8, 8, pipe.groups.clone());
    // minimal TGQ overlay on one site so the sampler takes the swap path
    let site = rt_first_tgq_site(&pipe);
    let per_group: Vec<_> = (0..pipe.groups.groups)
        .map(|g| tq_dit::quant::SiteParams::MrqSoftmax(
            tq_dit::quant::MrqSoftmax::new(1e-4 * (g + 1) as f32, 8)))
        .collect();
    qc.tgq.insert(site, per_group);
    let sampler = Sampler::new(&pipe.rt, &pipe.weights, qc,
                               cfg.timesteps).unwrap();
    let labels = vec![0i32; sampler.batch()];
    let (_, st) = sampler.sample(&labels, &mut Rng::new(1)).unwrap();
    // descending trajectory crosses each group exactly once
    assert_eq!(st.qp_swaps, pipe.groups.groups);
}

fn rt_first_tgq_site(pipe: &Pipeline) -> String {
    pipe.rt
        .manifest
        .sites()
        .iter()
        .find(|s| s.tgq)
        .expect("a tgq site")
        .name
        .clone()
}

#[test]
fn evaluator_separates_real_from_noise() {
    let dir = require_artifacts!();
    let cfg = small_cfg(&dir);
    let pipe = Pipeline::new(cfg).unwrap();
    let m = &pipe.rt.manifest;
    let il = m.model.img_size * m.model.img_size * m.model.channels;
    let n = m.batches.feat;
    let mut rng = Rng::new(9);

    // real synthetic images → tiny FID, confident IS
    let mut ev_real = Evaluator::new(&pipe.rt).unwrap();
    let mut imgs = vec![0.0f32; n * il];
    for i in 0..n {
        let mut tmp = vec![0.0f32; il];
        pipe.ds.render(i % 8, &mut rng, &mut tmp);
        imgs[i * il..(i + 1) * il].copy_from_slice(&tmp);
    }
    ev_real.push_images(&imgs).unwrap();
    let real = ev_real.finish().unwrap();

    // uniform noise images → far-off FID
    let mut ev_noise = Evaluator::new(&pipe.rt).unwrap();
    let noise: Vec<f32> = (0..n * il)
        .map(|_| rng.uniform_range(-1.0, 1.0))
        .collect();
    ev_noise.push_images(&noise).unwrap();
    let bad = ev_noise.finish().unwrap();

    assert!(real.fid < bad.fid * 0.1,
            "real {:.4} vs noise {:.4}", real.fid, bad.fid);
    assert!(real.sfid < bad.sfid, "{} vs {}", real.sfid, bad.sfid);
    assert!(real.is_score > 4.0, "IS on real: {}", real.is_score);
}

#[test]
fn evaluator_handles_ragged_tail_batches() {
    let dir = require_artifacts!();
    let cfg = small_cfg(&dir);
    let pipe = Pipeline::new(cfg).unwrap();
    let m = &pipe.rt.manifest;
    let il = m.model.img_size * m.model.img_size * m.model.channels;
    let mut rng = Rng::new(10);
    let mut ev = Evaluator::new(&pipe.rt).unwrap();
    // push 3, then 70, then 1 — forces pad + multi-flush + tail
    for n in [3usize, 70, 1] {
        let mut imgs = vec![0.0f32; n * il];
        for i in 0..n {
            let mut tmp = vec![0.0f32; il];
            pipe.ds.render(i % 8, &mut rng, &mut tmp);
            imgs[i * il..(i + 1) * il].copy_from_slice(&tmp);
        }
        ev.push_images(&imgs).unwrap();
    }
    let row = ev.finish().unwrap();
    assert_eq!(row.n, 74);
    assert!(row.fid.is_finite() && row.is_score.is_finite());
}

#[test]
fn fp_pipeline_cell_is_cheap_and_scores_well() {
    let dir = require_artifacts!();
    let cfg = small_cfg(&dir);
    let pipe = Pipeline::new(cfg.clone()).unwrap();
    let (qc, cost) = pipe
        .calibrate(Method::Fp, &mut Rng::new(0))
        .unwrap();
    assert_eq!(cost.evals, 0);
    let row = pipe.evaluate(&qc, 16, 3).unwrap();
    assert_eq!(row.n, 16);
    assert!(row.fid.is_finite());
    // trained model beats noise by a wide margin (noise FID is >100x)
    assert!(row.fid < 50.0, "FP FID {}", row.fid);
}

#[test]
fn serve_end_to_end_fp() {
    let dir = require_artifacts!();
    let mut cfg = small_cfg(&dir);
    cfg.timesteps = 10;
    let server = tq_dit::serve::GenServer::start(cfg, Method::Fp);
    let (id0, rx0) = server
        .submit(tq_dit::serve::GenRequest { class: 2, n: 5 })
        .unwrap();
    let (id1, rx1) = server
        .submit(tq_dit::serve::GenRequest {
            class: 7,
            n: 20, // spans two fixed-size batches
        })
        .unwrap();
    let r0 = rx0.recv().unwrap().unwrap();
    let r1 = rx1.recv().unwrap().unwrap();
    assert_eq!(r0.id, id0);
    assert_eq!(r1.id, id1);
    assert_eq!(r0.images.len(), 5 * 16 * 16 * 3);
    assert_eq!(r1.images.len(), 20 * 16 * 16 * 3);
    assert!(r0.images.iter().all(|v| v.is_finite()));
    let stats = server.shutdown();
    assert_eq!(stats.requests, 2);
    assert_eq!(stats.images, 25);
    assert!(stats.batches >= 2);
    assert_eq!(stats.failed_requests, 0);
}

#[test]
fn serve_sharded_concurrent_load() {
    // multiple client threads against a 2-worker shard: every request
    // must come back with exactly n·img_len finite pixels, and the
    // drain-on-shutdown accounting must balance.
    let dir = require_artifacts!();
    let mut cfg = small_cfg(&dir);
    cfg.timesteps = 5;
    let server = tq_dit::serve::GenServer::with_workers(cfg, Method::Fp, 2);
    let il = 16 * 16 * 3;
    let total = std::sync::atomic::AtomicU64::new(0);
    std::thread::scope(|s| {
        for c in 0..3usize {
            let server = &server;
            let total = &total;
            s.spawn(move || {
                for i in 0..4usize {
                    let n = 1 + (c * 5 + i * 3) % 7;
                    total.fetch_add(n as u64,
                                    std::sync::atomic::Ordering::Relaxed);
                    let (_, rx) = server
                        .submit(tq_dit::serve::GenRequest {
                            class: ((c + i) % 8) as i32,
                            n,
                        })
                        .unwrap();
                    let resp = rx.recv().unwrap().unwrap();
                    assert_eq!(resp.images.len(), n * il);
                    assert!(resp.images.iter().all(|v| v.is_finite()));
                    assert!(resp.latency_s >= 0.0);
                }
            });
        }
    });
    let stats = server.shutdown();
    assert_eq!(stats.requests, 12);
    assert_eq!(stats.images,
               total.load(std::sync::atomic::Ordering::Relaxed));
    assert_eq!(stats.failed_requests, 0);
    assert_eq!(stats.workers.len(), 2);
    // the calibrate-once path and padding accounting both ran; each
    // dispatch fills exactly one lowered rung (rungs may differ in
    // size once the manifest carries a ladder, so compare against the
    // per-rung capacity rather than assuming one fixed batch)
    let dispatched: u64 = stats.images + stats.padded_slots;
    let capacity: u64 = stats
        .rungs
        .iter()
        .map(|r| r.rung as u64 * r.batches)
        .sum();
    assert_eq!(dispatched, capacity,
               "padding must fill whole lowered rungs");
}

#[test]
fn serve_warm_calib_cache_cold_start_skips_calibration() {
    // Cold start populates the persistent cache; a second server with
    // the same config + artifacts must come up on a cache hit and
    // produce *identical* images — the round-tripped QuantConfig is
    // bit-for-bit the one fresh calibration produced (the no-quantize
    // guarantee itself is asserted by the counting-hook unit test in
    // serve::server; quantize_runs() is process-global and other tests
    // in this binary run concurrently).
    let dir = require_artifacts!();
    let mut cfg = small_cfg(&dir);
    cfg.timesteps = 10;
    cfg.groups = 5;
    cfg.calib_per_group = 2;
    cfg.candidates = 8;
    let cache_dir = std::env::temp_dir().join(format!(
        "tqdit_itest_calib_cache_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&cache_dir);
    cfg.calib_cache = Some(cache_dir.to_str().unwrap().to_string());

    let run = |cfg: &RunConfig| {
        let server = tq_dit::serve::GenServer::with_workers(
            cfg.clone(), Method::TqDit, 1);
        let (_, rx) = server
            .submit(tq_dit::serve::GenRequest { class: 3, n: 2 })
            .unwrap();
        let images = rx.recv().unwrap().unwrap().images;
        (images, server.shutdown())
    };

    let (cold_images, cold) = run(&cfg);
    assert_eq!(cold.calib_cache_misses, 1, "first start must miss");
    assert_eq!(cold.calib_cache_hits, 0);
    assert!(cold.calib_cold_start_ms > 0.0);

    let (warm_images, warm) = run(&cfg);
    assert_eq!(warm.calib_cache_hits, 1, "second start must hit");
    assert_eq!(warm.calib_cache_misses, 0);
    assert_eq!(cold_images, warm_images,
               "cached config must reproduce fresh calibration exactly");

    // a corrupted entry degrades to a miss (fresh calibration), with
    // identical output and no panic anywhere in the load path
    let pipe = Pipeline::new(cfg.clone()).unwrap();
    let key = pipe.cache_key(Method::TqDit).unwrap();
    let cache = pipe.calib_cache().unwrap();
    let entry = cache.path_for(&key);
    assert!(entry.exists());
    std::fs::write(&entry, b"\x00\xffnot json").unwrap();
    drop(pipe);
    let (repaired_images, repaired) = run(&cfg);
    assert_eq!(repaired.calib_cache_misses, 1);
    assert_eq!(repaired_images, cold_images,
               "fallback recalibration must match the original");

    let _ = std::fs::remove_dir_all(&cache_dir);
}

#[test]
fn serve_submit_after_worker_failure_errors_not_panics() {
    // no artifacts needed — this *relies* on the pipeline build failing.
    // The old server panicked the client on `.expect("server worker
    // alive")`; now every path must produce a typed error.
    let cfg = RunConfig {
        artifacts: "/nonexistent/tq-dit-missing-artifacts".into(),
        ..RunConfig::default()
    };
    let server = tq_dit::serve::GenServer::start(cfg, Method::Fp);
    loop {
        match server.submit(tq_dit::serve::GenRequest { class: 0, n: 1 }) {
            Err(e) => {
                // rejected up front once the worker's death was recorded
                assert!(!e.to_string().is_empty());
                break;
            }
            Ok((_, rx)) => {
                // accepted before the worker died: the queued request
                // must still fail with a typed error, never hang
                assert!(rx.recv().unwrap().is_err());
                std::thread::sleep(std::time::Duration::from_millis(5));
            }
        }
    }
    let stats = server.shutdown();
    assert_eq!(stats.images, 0);
    assert!(stats.workers[0].failed);
}

#[test]
fn train_step_artifact_reduces_loss_from_scratch() {
    // the loss-curve path: drive train_step with *re-initialized* params
    // (zeros for adaLN etc. would need init logic; instead perturb the
    // trained weights heavily and verify the loss drops back).
    let dir = require_artifacts!();
    let cfg = small_cfg(&dir);
    let pipe = Pipeline::new(cfg).unwrap();
    let m = pipe.rt.manifest.clone();
    let npar = m.n_params();
    let tb = m.batches.train;
    let il = m.model.img_size * m.model.img_size * m.model.channels;
    let mut rng = Rng::new(21);

    let mut params = pipe.weights.tensors.clone();
    for t in params.iter_mut() {
        for v in t.data.iter_mut() {
            *v += 0.05 * rng.normal() as f32; // heavy perturbation
        }
    }
    let mut mstate: Vec<Tensor> =
        params.iter().map(|t| Tensor::zeros(t.shape.clone())).collect();
    let mut vstate = mstate.clone();
    let d = &m.diffusion;
    let sched = DdpmSchedule::new(d.train_steps, d.beta_start, d.beta_end,
                                  d.train_steps);
    let abar = Tensor::new(
        vec![d.train_steps],
        sched.train_alpha_bars.iter().map(|&v| v as f32).collect(),
    );

    let mut losses = Vec::new();
    for step in 0..8 {
        let (x0, y) = pipe.ds.sample_batch(tb, &mut rng);
        let t: Vec<i32> =
            (0..tb).map(|_| rng.below(d.train_steps) as i32).collect();
        let eps = rng.normal_vec(tb * il);
        let mut bufs = Vec::new();
        for tsr in params.iter().chain(&mstate).chain(&vstate) {
            bufs.push(pipe.rt.upload(tsr).unwrap());
        }
        bufs.push(pipe.rt.upload_i32(&[step as i32], &[]).unwrap());
        bufs.push(pipe.rt.upload(&Tensor::new(
            vec![tb, m.model.img_size, m.model.img_size, m.model.channels],
            x0)).unwrap());
        bufs.push(pipe.rt.upload_i32(&t, &[tb]).unwrap());
        bufs.push(pipe.rt.upload_i32(&y, &[tb]).unwrap());
        bufs.push(pipe.rt.upload(&Tensor::new(
            vec![tb, m.model.img_size, m.model.img_size, m.model.channels],
            eps)).unwrap());
        bufs.push(pipe.rt.upload(&abar).unwrap());
        let inputs: Vec<&xla::PjRtBuffer> = bufs.iter().collect();
        let outs = pipe.rt.run_buffers("train_step", &inputs).unwrap();
        for (dst, src) in params.iter_mut().zip(&outs[..npar]) {
            *dst = src.clone();
        }
        for (dst, src) in mstate.iter_mut().zip(&outs[npar..2 * npar]) {
            *dst = src.clone();
        }
        for (dst, src) in vstate.iter_mut().zip(&outs[2 * npar..3 * npar]) {
            *dst = src.clone();
        }
        losses.push(outs[3 * npar].data[0]);
    }
    assert!(losses.last().unwrap() < &losses[0],
            "loss did not drop: {losses:?}");
}
