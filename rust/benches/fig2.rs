//! Fig. 2 regenerator: post-softmax / post-GELU value distributions in
//! DiT blocks — the asymmetry that motivates MRQ — as console
//! histograms (CSV via `examples/distributions.rs`).

#[path = "common.rs"]
mod common;

use tq_dit::coordinator::pipeline::Pipeline;
use tq_dit::tensor::stats::Histogram;
use tq_dit::util::rng::Rng;

fn render(h: &Histogram, label: &str, rows: usize) {
    println!("\n{label} ({} samples, {} under / {} over range):", h.count,
             h.underflow, h.overflow);
    let d = h.densities();
    let step = d.len().div_ceil(rows);
    let dmax = d.iter().map(|x| x.1).fold(0.0, f64::max);
    for chunk in d.chunks(step) {
        let c = chunk[chunk.len() / 2].0;
        let v: f64 = chunk.iter().map(|x| x.1).sum::<f64>()
            / chunk.len() as f64;
        let n = ((v / dmax.max(1e-12)) * 50.0).round() as usize;
        println!("{c:>8.3} | {}", "#".repeat(n.min(50)));
    }
}

fn main() -> anyhow::Result<()> {
    let mut cfg = common::bench_config();
    cfg.calib_per_group = cfg.calib_per_group.max(8);
    common::banner("Fig. 2: activation distributions (softmax / GELU)",
                   &cfg);
    let pipe = Pipeline::new(cfg.clone())?;
    let mut rng = Rng::new(cfg.seed);
    let t0 = std::time::Instant::now();
    let (_, ev) = pipe.grouped_evidence(&mut rng)?;
    println!("capture: {:.1}s over {} batches", t0.elapsed().as_secs_f64(),
             ev.batches_run);

    render(&ev.softmax_hist, "Fig. 2a post-softmax", 16);
    render(&ev.gelu_hist, "Fig. 2b post-GELU", 16);

    let sm = &ev.softmax_hist;
    let below = sm.bins[..sm.bins.len() / 8].iter().sum::<u64>() as f64
        / sm.count.max(1) as f64;
    println!("\npaper shape: post-softmax mass concentrated near 0 \
              (ours: {:.1}% below 0.125) and post-GELU negatively skewed \
              with a bounded tail.", 100.0 * below);
    Ok(())
}
