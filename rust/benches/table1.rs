//! Table I regenerator: FID/sFID/IS at T=250 (bench-sized T by default)
//! for FP + Q-Diffusion + PTQD + PTQ4DiT + TQ-DiT, at W8A8 and W6A6.

#[path = "common.rs"]
mod common;

use tq_dit::coordinator::pipeline::{Method, Pipeline};
use tq_dit::coordinator::QuantConfig;
use tq_dit::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let mut cfg = common::bench_config();
    if common::full() {
        cfg.timesteps = 250;
    }
    common::banner("Table I: T=250 quality comparison", &cfg);

    for (w, a) in [(8u32, 8u32), (6, 6)] {
        cfg.wbits = w;
        cfg.abits = a;
        println!("\n-- W{w}A{a} --");
        println!("{:<22} {:>9} {:>9} {:>8} {:>9}", "method", "FID", "sFID",
                 "IS", "calib(s)");
        let pipe = Pipeline::new(cfg.clone())?;
        let fp = QuantConfig::fp(pipe.groups.clone());
        let t0 = std::time::Instant::now();
        let r = pipe.evaluate(&fp, cfg.eval_images, cfg.seed ^ 0xe7a1)?;
        println!("{:<22} {:>9.3} {:>9.3} {:>8.3} {:>9}  (eval {:.1}s)",
                 "FP (32/32)", r.fid, r.sfid, r.is_score, "-",
                 t0.elapsed().as_secs_f64());
        for method in Method::ALL_QUANT {
            let mut rng = Rng::new(cfg.seed ^ 0x5eed);
            let (qc, cost) = pipe.calibrate(method, &mut rng)?;
            let row = pipe.evaluate(&qc, cfg.eval_images,
                                    cfg.seed ^ 0xe7a1)?;
            println!("{:<22} {:>9.3} {:>9.3} {:>8.3} {:>9.1}",
                     method.name(), row.fid, row.sfid, row.is_score,
                     cost.wall_s);
        }
    }
    println!("\npaper shape: all ≈ FP at W8A8 (TQ-DiT closest: 4.91 vs \
              4.62 FP); at W6A6 baselines blow up (28.9/17.6/20.5 FID) \
              while TQ-DiT holds 8.58.");
    Ok(())
}
