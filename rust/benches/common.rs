//! Shared scaffolding for the bench targets (`harness = false`).
//!
//! Every table/figure bench regenerates its experiment end-to-end and
//! prints the paper-shaped rows plus phase timings. Sizes default to a
//! CPU-friendly working set; set `TQDIT_BENCH_FULL=1` for paper-sized
//! runs (T=250/100, n=32 per group, 256+ eval images), or override the
//! individual `TQDIT_BENCH_*` vars.

use std::collections::BTreeMap;

use tq_dit::util::config::RunConfig;
use tq_dit::util::json::Json;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// Merge one named section into a `BENCH_*.json` scorecard next to the
/// cargo manifest. Read-parse-merge-dump, so independent bench steps
/// (threaded/reactor net smokes, batching, calibration, step-reuse)
/// accumulate into one file per scorecard instead of clobbering each
/// other; an unreadable or corrupt file degrades to a fresh one.
pub fn write_bench_section(file: &str, section: &str,
                           fields: Vec<(&str, Json)>)
                           -> anyhow::Result<std::path::PathBuf> {
    let path = match std::env::var("CARGO_MANIFEST_DIR") {
        Ok(d) => std::path::PathBuf::from(d).join(file),
        Err(_) => std::path::PathBuf::from(file),
    };
    let mut root = match std::fs::read_to_string(&path) {
        Ok(text) => match Json::parse(&text) {
            Ok(Json::Obj(o)) => o,
            _ => BTreeMap::new(),
        },
        Err(_) => BTreeMap::new(),
    };
    let mut sec = BTreeMap::new();
    for (k, v) in fields {
        sec.insert(k.to_string(), v);
    }
    root.insert(section.to_string(), Json::Obj(sec));
    std::fs::write(&path, Json::Obj(root).dump()).map_err(|e| {
        anyhow::anyhow!("writing {}: {e}", path.display())
    })?;
    println!("\nwrote {} ({section} section)", path.display());
    Ok(path)
}

pub fn full() -> bool {
    std::env::var("TQDIT_BENCH_FULL").as_deref() == Ok("1")
}

/// Bench-sized run configuration (or paper-sized under `full()`).
pub fn bench_config() -> RunConfig {
    let mut cfg = RunConfig::default();
    if full() {
        cfg.timesteps = env_usize("TQDIT_BENCH_T", 250);
        cfg.calib_per_group = env_usize("TQDIT_BENCH_CALIB", 32);
        cfg.eval_images = env_usize("TQDIT_BENCH_EVAL", 256);
    } else {
        cfg.timesteps = env_usize("TQDIT_BENCH_T", 40);
        cfg.calib_per_group = env_usize("TQDIT_BENCH_CALIB", 6);
        cfg.eval_images = env_usize("TQDIT_BENCH_EVAL", 40);
        cfg.candidates = env_usize("TQDIT_BENCH_CANDIDATES", 24);
    }
    cfg
}

pub fn banner(what: &str, cfg: &RunConfig) {
    println!("=== {what} ===");
    println!(
        "config: T={} G={} n/group={} R={} candidates={} eval={} {}",
        cfg.timesteps, cfg.groups, cfg.calib_per_group, cfg.rounds,
        cfg.candidates, cfg.eval_images,
        if full() { "(paper-sized)" } else { "(bench-sized; TQDIT_BENCH_FULL=1 for paper scale)" }
    );
}
