//! Shared scaffolding for the bench targets (`harness = false`).
//!
//! Every table/figure bench regenerates its experiment end-to-end and
//! prints the paper-shaped rows plus phase timings. Sizes default to a
//! CPU-friendly working set; set `TQDIT_BENCH_FULL=1` for paper-sized
//! runs (T=250/100, n=32 per group, 256+ eval images), or override the
//! individual `TQDIT_BENCH_*` vars.

use tq_dit::util::config::RunConfig;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

pub fn full() -> bool {
    std::env::var("TQDIT_BENCH_FULL").as_deref() == Ok("1")
}

/// Bench-sized run configuration (or paper-sized under `full()`).
pub fn bench_config() -> RunConfig {
    let mut cfg = RunConfig::default();
    if full() {
        cfg.timesteps = env_usize("TQDIT_BENCH_T", 250);
        cfg.calib_per_group = env_usize("TQDIT_BENCH_CALIB", 32);
        cfg.eval_images = env_usize("TQDIT_BENCH_EVAL", 256);
    } else {
        cfg.timesteps = env_usize("TQDIT_BENCH_T", 40);
        cfg.calib_per_group = env_usize("TQDIT_BENCH_CALIB", 6);
        cfg.eval_images = env_usize("TQDIT_BENCH_EVAL", 40);
        cfg.candidates = env_usize("TQDIT_BENCH_CANDIDATES", 24);
    }
    cfg
}

pub fn banner(what: &str, cfg: &RunConfig) {
    println!("=== {what} ===");
    println!(
        "config: T={} G={} n/group={} R={} candidates={} eval={} {}",
        cfg.timesteps, cfg.groups, cfg.calib_per_group, cfg.rounds,
        cfg.candidates, cfg.eval_images,
        if full() { "(paper-sized)" } else { "(bench-sized; TQDIT_BENCH_FULL=1 for paper scale)" }
    );
}
