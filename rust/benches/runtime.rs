//! PJRT runtime benches: artifact compile time, per-step execute
//! latency (the sampler's budget), upload overheads, end-to-end
//! sampling throughput — FP vs quantized path — the serve stack's
//! adaptive-batching policy (ladder vs fixed under trickle / steady /
//! burst load), and the cross-node loopback cluster (2 shard nodes on
//! 127.0.0.1): one killed mid-load permanently, then the elasticity
//! run — control-plane liveness under ~10 MiB responses (zero false
//! node-deaths) and a kill-then-restart that must end in re-admission
//! with conservation intact across the flap.
//!
//! Smoke gates (no AOT artifacts, no PJRT — the CI steps):
//! `TQDIT_BENCH_SMOKE=1` runs the mock-backend adaptive-batching and
//! step-reuse sections; `TQDIT_BENCH_REUSE=1` only the step-reuse
//! section; `TQDIT_NET_SMOKE=1` only the loopback cluster sections.
//! The net sections run on the event-driven reactor transport by
//! default (mirroring the `--reactor` flag); `TQDIT_NET_REACTOR=0`
//! opts back into thread-per-connection — CI runs both. They also run
//! a connection-capacity smoke (≥1k idle loopback connections on one
//! reactor node, thread count O(workers)), a live `/metrics` scrape
//! against a metrics-enabled reactor node (scraped p95 must match the
//! shutdown `ServerStats` within histogram bucket error), a tracing
//! on/off overhead comparison, and write the serve scorecard to
//! `BENCH_serve.json`, one section per transport mode (img/s, p95
//! latency, padding, connect cold-start ms, max concurrent
//! connections) plus `batching`, `calibration` and `tracing_overhead`
//! sections. The
//! step-reuse section writes `BENCH_sample.json` (img/s with and
//! without reuse, per-step ms, reuse rate, δ=0 image-hash equality)
//! and exits nonzero unless δ=0 is byte-identical to the plain loop,
//! the default-δ synthetic pipeline strictly beats the no-reuse
//! baseline, and `reuse_hits` surfaces in `ServerStats`.

#[path = "common.rs"]
mod common;

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use tq_dit::coordinator::pipeline::{Method, Pipeline};
use tq_dit::coordinator::QuantConfig;
use tq_dit::sampler::{reuse, Sampler};
use tq_dit::sched::{DdpmSchedule, TimeGroups};
use tq_dit::serve::net::reactor::{
    process_thread_count, raise_nofile_limit,
};
use tq_dit::serve::{
    Cluster, ClusterOpts, GenBackend, GenRequest, GenServer,
    HealthPolicy, NetClient, NetClientOpts, NodeOpts, NodeServer,
    Router, RouterOpts, ServerStats, WorkerBody, WorkerHandle,
};
use tq_dit::tensor::Tensor;
use tq_dit::util::bench::Bench;
use tq_dit::util::json::Json;
use tq_dit::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let smoke = std::env::var("TQDIT_BENCH_SMOKE").as_deref() == Ok("1");
    let net_smoke = std::env::var("TQDIT_NET_SMOKE").as_deref() == Ok("1");
    let reuse_only =
        std::env::var("TQDIT_BENCH_REUSE").as_deref() == Ok("1");
    let full = !smoke && !net_smoke && !reuse_only;
    if full {
        pjrt_benches()?;
    }
    if full || smoke {
        adaptive_batching_bench()?;
        lint_bench()?;
    }
    if full || smoke || reuse_only {
        step_reuse_bench()?;
    }
    if full || net_smoke {
        println!(
            "\n== net transport: {} ==",
            if reactor_mode() { "reactor" } else { "threaded" }
        );
        let metrics = cluster_loopback_bench()?;
        cluster_liveness_bench()?;
        cluster_flap_bench()?;
        let max_conns = connection_count_bench()?;
        write_serve_report(&metrics, max_conns)?;
        metrics_scrape_bench()?;
        tracing_overhead_bench()?;
    }
    Ok(())
}

/// Whole-program lint wall-time: the lint gates every CI push, so its
/// cost is part of the inner loop — track it next to the serve numbers
/// as the `lint` section of `BENCH_serve.json`, split by phase
/// (parse+index, call-graph build, rule passes, stats-plumbing). Also
/// doubles as the bench-side dogfood: a finding here fails the run.
fn lint_bench() -> anyhow::Result<()> {
    let rs = std::path::PathBuf::from("rust/src");
    let root = if rs.is_dir() { rs } else { "src".into() };
    let run = tq_dit::analysis::lint_tree(std::slice::from_ref(&root))?;
    anyhow::ensure!(
        run.findings.is_empty(),
        "lint found {} finding(s) during bench",
        run.findings.len()
    );
    let ms = |ns: u128| ns as f64 / 1e6;
    let phase = |label: &str| {
        ms(run
            .timings
            .iter()
            .filter(|(l, _)| *l == label || (label == "rules" && *l != "parse+index" && *l != "graph" && *l != "stats-plumbing"))
            .map(|(_, ns)| ns)
            .sum())
    };
    println!(
        "\nwhole-program lint: {} files, {} fns, {} inferred blocking, \
         {:.1} ms wall",
        run.files,
        run.graph.fn_count(),
        run.graph.blocking_count(),
        ms(run.wall_ns)
    );
    common::write_bench_section("BENCH_serve.json", "lint", vec![
        ("files", Json::Num(run.files as f64)),
        ("fns", Json::Num(run.graph.fn_count() as f64)),
        ("inferred_blocking", Json::Num(run.graph.blocking_count() as f64)),
        ("wall_ms", Json::Num(ms(run.wall_ns))),
        ("parse_index_ms", Json::Num(phase("parse+index"))),
        ("graph_ms", Json::Num(phase("graph"))),
        ("rule_pass_ms", Json::Num(phase("rules"))),
        ("stats_plumbing_ms", Json::Num(phase("stats-plumbing"))),
    ])?;
    Ok(())
}

/// Transport mode for the net sections: the poll-based reactor by
/// default (mirroring `RunConfig`); `TQDIT_NET_REACTOR=0` opts back
/// into thread-per-connection.
fn reactor_mode() -> bool {
    std::env::var("TQDIT_NET_REACTOR").as_deref() != Ok("0")
}

fn net_node_opts() -> NodeOpts {
    NodeOpts { reactor: reactor_mode(), ..NodeOpts::default() }
}

fn net_cluster_opts() -> ClusterOpts {
    ClusterOpts { reactor: reactor_mode(), ..ClusterOpts::default() }
}

/// The serve scorecard one net-smoke run produces (one transport mode).
struct ServeMetrics {
    img_per_s: f64,
    latency_p95_s: f64,
    padded_slots: u64,
    batch_fill: f64,
    /// `Cluster::connect` wall time: dials + handshakes + (reactor
    /// mode) reactor spawn and connection registration.
    cold_start_ms: f64,
}

/// Merge this run's section into `BENCH_serve.json` (next to the cargo
/// manifest, so threaded and reactor CI steps land in one file).
fn write_serve_report(m: &ServeMetrics, max_conns: usize)
                      -> anyhow::Result<()> {
    let mode = if reactor_mode() { "reactor" } else { "threaded" };
    common::write_bench_section("BENCH_serve.json", mode, vec![
        ("img_per_s", Json::Num(m.img_per_s)),
        ("latency_p95_s", Json::Num(m.latency_p95_s)),
        ("padded_slots", Json::Num(m.padded_slots as f64)),
        ("batch_fill", Json::Num(m.batch_fill)),
        ("cold_start_ms", Json::Num(m.cold_start_ms)),
        ("max_concurrent_connections", Json::Num(max_conns as f64)),
    ])?;
    Ok(())
}

fn pjrt_benches() -> anyhow::Result<()> {
    let mut cfg = common::bench_config();
    cfg.timesteps = 50;
    cfg.calib_per_group = 4;
    common::banner("runtime: PJRT execute/upload/sampling", &cfg);
    let pipe = Pipeline::new(cfg.clone())?;
    let m = pipe.rt.manifest.clone();
    let bch = Bench::default();
    let mut rng = Rng::new(3);

    // compile (cold) timings are logged by Runtime; warm execute below.
    let b = m.batches.sample_max();
    let il = m.model.img_size * m.model.img_size * m.model.channels;
    let wbufs = pipe.rt.upload_all(&pipe.weights.tensors)?;
    let x = Tensor::new(vec![b, m.model.img_size, m.model.img_size,
                             m.model.channels],
                        rng.normal_vec(b * il));
    let t = vec![25i32; b];
    let y = vec![0i32; b];

    // upload micro-bench
    bch.run("upload/x(16x16x16x3)", || {
        std::hint::black_box(pipe.rt.upload(&x).unwrap());
    });

    // FP forward execute
    let xb = pipe.rt.upload(&x)?;
    let tb = pipe.rt.upload_i32(&t, &[b])?;
    let yb = pipe.rt.upload_i32(&y, &[b])?;
    let r = bch.run("execute/dit_fp_sample", || {
        let mut inputs: Vec<&xla::PjRtBuffer> = wbufs.iter().collect();
        inputs.extend([&xb, &tb, &yb]);
        std::hint::black_box(
            pipe.rt.run_buffers("dit_fp_sample", &inputs).unwrap());
    });
    println!("  -> {:.1} img/s single-batch", r.per_sec(b));

    // quantized forward execute (pallas-lowered graph)
    let qp = Tensor::new(vec![m.qp_len], vec![0.0; m.qp_len]);
    let qpb = pipe.rt.upload(&qp)?;
    let r = bch.run("execute/dit_quant(bypass)", || {
        let mut inputs: Vec<&xla::PjRtBuffer> = wbufs.iter().collect();
        inputs.extend([&xb, &tb, &yb, &qpb]);
        std::hint::black_box(
            pipe.rt.run_buffers("dit_quant", &inputs).unwrap());
    });
    println!("  -> {:.1} img/s single-batch", r.per_sec(b));

    // end-to-end sampling throughput: FP vs calibrated TQ-DiT
    let fp = QuantConfig::fp(pipe.groups.clone());
    let sampler = Sampler::new(&pipe.rt, &pipe.weights, fp, cfg.timesteps)?;
    let labels: Vec<i32> = (0..b).map(|i| (i % 8) as i32).collect();
    let quick = Bench { warmup: 1, iters: 3, max_total_s: 120.0 };
    let r = quick.run("sample/fp(T=50,batch=16)", || {
        std::hint::black_box(sampler.sample(&labels, &mut rng).unwrap());
    });
    println!("  -> {:.2} img/s end-to-end", r.per_sec(b));

    let mut crng = Rng::new(cfg.seed ^ 0x5eed);
    let (qc, _) = pipe.calibrate(Method::TqDit, &mut crng)?;
    let sampler_q = Sampler::new(&pipe.rt, &pipe.weights, qc.clone(),
                                 cfg.timesteps)?;
    let r = quick.run("sample/tq-dit(T=50,batch=16)", || {
        std::hint::black_box(sampler_q.sample(&labels, &mut rng).unwrap());
    });
    println!("  -> {:.2} img/s end-to-end", r.per_sec(b));

    // step reuse on the real artifacts: setting δ=0 must be
    // byte-identical to the default-constructed sampler, and the
    // calibrated drift at the default δ should trade forward passes
    // for fused host updates
    let mut sampler_z = Sampler::new(&pipe.rt, &pipe.weights, qc.clone(),
                                     cfg.timesteps)?;
    sampler_z.set_reuse_delta(0.0);
    let mut ra = Rng::new(cfg.seed ^ 0xd1ff);
    let mut rb = Rng::new(cfg.seed ^ 0xd1ff);
    let (imgs_a, _) = sampler_q.sample(&labels, &mut ra)?;
    let (imgs_b, _) = sampler_z.sample(&labels, &mut rb)?;
    anyhow::ensure!(
        hash_f32(&imgs_a) == hash_f32(&imgs_b),
        "δ=0 sampler diverged from the default-constructed one"
    );
    let mut sampler_r = sampler_z;
    sampler_r.set_reuse_delta(cfg.reuse_delta);
    let mut rr = Rng::new(cfg.seed ^ 0xd1ff);
    let (imgs_r, st) = sampler_r.sample(&labels, &mut rr)?;
    anyhow::ensure!(imgs_r.iter().all(|v| v.is_finite()),
                    "reuse trajectory produced non-finite pixels");
    println!(
        "  reuse(δ={}): {}/{} steps from cache, {} uploads saved",
        sampler_r.reuse_delta(), st.reuse_hits, cfg.timesteps,
        st.uploads_saved
    );
    drop(sampler_r);

    // per-artifact exec stats (observability)
    println!("\nper-artifact cumulative exec stats:");
    for (name, st) in pipe.rt.stats() {
        println!("  {name:<18} {:>6} calls  {:>9.3}s total  {:>8.2}ms/call",
                 st.calls, st.total_s,
                 1e3 * st.total_s / st.calls.max(1) as f64);
    }

    // sharded generation service: aggregate throughput at 1/2/4 workers
    // on a fixed mixed-size synthetic workload (FP path, so worker
    // startup cost is pipeline build only)
    drop(sampler_q);
    drop(sampler);
    drop((xb, tb, yb, qpb, wbufs));
    drop(pipe);
    println!("\nsharded serve scaling (FP, T={}):", cfg.timesteps);
    let n_req = 12usize;
    let mut base_thr = 0.0f64;
    for &w in &[1usize, 2, 4] {
        let server = GenServer::with_workers(cfg.clone(), Method::Fp, w);
        // keep worker startup (pipeline build) out of the steady-state
        // throughput window; a dead worker ends the wait
        while server.ready_workers() < server.live_workers() {
            std::thread::sleep(std::time::Duration::from_millis(10));
        }
        let t0 = std::time::Instant::now();
        let mut rxs = Vec::with_capacity(n_req);
        for i in 0..n_req {
            let req = GenRequest {
                class: (i % 8) as i32,
                n: 4 + (i * 3) % 9,
            };
            rxs.push(server.submit(req)?);
        }
        let mut images = 0usize;
        for (_, rx) in rxs {
            images += rx.recv()??.images.len() / il;
        }
        let wall = t0.elapsed().as_secs_f64();
        let thr = images as f64 / wall;
        if w == 1 {
            base_thr = thr;
        }
        println!(
            "  workers={w}: {images} imgs in {wall:.2}s  {thr:.2} img/s  \
             ({:.2}x vs 1 worker)",
            thr / base_thr.max(1e-9)
        );
        server.shutdown().print();
    }

    // persistent calibration cache: server cold start, cold vs warm.
    // The first start runs the full MRQ/TGQ pipeline and persists the
    // config; the second loads it and must reach ready in a fraction
    // of the time (restart costs seconds, not a recalibration).
    let cache_dir = std::env::temp_dir().join(format!(
        "tqdit_bench_calib_cache_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&cache_dir);
    let mut ccfg = cfg.clone();
    ccfg.timesteps = 20;
    ccfg.groups = 5;
    ccfg.calib_per_group = 2;
    ccfg.rounds = 1;
    ccfg.candidates = 12;
    ccfg.calib_cache = Some(cache_dir.to_string_lossy().into_owned());
    println!("\ncalibration cache: tq-dit server cold start, cold vs warm:");
    let mut cold_ready_s = 0.0f64;
    let mut warm_ready_s = 0.0f64;
    let mut cold_calib_ms = 0.0f64;
    for label in ["cold", "warm"] {
        let t0 = std::time::Instant::now();
        let server =
            GenServer::with_workers(ccfg.clone(), Method::TqDit, 1);
        while server.ready_workers() < 1 && server.live_workers() > 0 {
            std::thread::sleep(std::time::Duration::from_millis(10));
        }
        let ready_s = t0.elapsed().as_secs_f64();
        let stats = server.shutdown();
        let outcome = if stats.calib_cache_hits > 0 { "hit" } else { "miss" };
        if label == "cold" {
            cold_ready_s = ready_s;
            cold_calib_ms = stats.calib_cold_start_ms;
            println!(
                "  {label}: ready in {ready_s:.2}s  (calib {:.0} ms, \
                 cache {outcome}, {} quantize runs so far)",
                stats.calib_cold_start_ms,
                tq_dit::coordinator::quantize::quantize_runs()
            );
        } else {
            warm_ready_s = ready_s;
            println!(
                "  {label}: ready in {ready_s:.2}s  (calib {:.0} ms, \
                 cache {outcome}, {:.1}x faster cold start)",
                stats.calib_cold_start_ms,
                cold_ready_s / ready_s.max(1e-9)
            );
        }
    }
    let _ = std::fs::remove_dir_all(&cache_dir);
    common::write_bench_section("BENCH_serve.json", "calibration", vec![
        ("cold_ready_s", Json::Num(cold_ready_s)),
        ("warm_ready_s", Json::Num(warm_ready_s)),
        ("cold_calib_ms", Json::Num(cold_calib_ms)),
        ("warm_speedup",
         Json::Num(cold_ready_s / warm_ready_s.max(1e-9))),
    ])?;
    Ok(())
}

// ---- adaptive batching: ladder vs fixed under shaped load --------------

/// Mock backend whose per-call cost is proportional to the dispatched
/// rung, so padded slots burn wall-clock exactly like real compute
/// would (per-step execute time dominates the low-bit serve cost).
struct ShapedBackend {
    rungs: Vec<usize>,
    il: usize,
    cost_per_slot: Duration,
}

impl GenBackend for ShapedBackend {
    fn rungs(&self) -> Vec<usize> {
        self.rungs.clone()
    }
    fn img_len(&self) -> usize {
        self.il
    }
    fn generate(&mut self, labels: &[i32]) -> anyhow::Result<Vec<f32>> {
        std::thread::sleep(self.cost_per_slot * labels.len() as u32);
        Ok(vec![0.0; labels.len() * self.il])
    }
}

/// Drive one scenario against one ladder; returns the shutdown stats.
fn drive_scenario(rungs: Vec<usize>, linger: Duration, scenario: &str)
                  -> anyhow::Result<ServerStats> {
    let il = 4usize;
    let body: Arc<WorkerBody> =
        Arc::new(move |h: WorkerHandle| -> anyhow::Result<()> {
            let mut b = ShapedBackend {
                rungs: rungs.clone(),
                il,
                cost_per_slot: Duration::from_millis(1),
            };
            h.serve(&mut b)
        });
    let router = Router::start(
        RouterOpts { workers: 1, linger, ..RouterOpts::default() },
        body,
    );
    match scenario {
        // sparse singles: each request waited out before the next, the
        // inter-arrival gap far exceeding the service time
        "trickle" => {
            for i in 0..24usize {
                let (_, rx) = router
                    .submit(GenRequest { class: (i % 8) as i32, n: 1 })?;
                rx.recv()??;
            }
        }
        // full-batch requests back to back: the top rung stays filled
        "steady" => {
            let rxs = (0..6usize)
                .map(|i| {
                    router.submit(GenRequest { class: (i % 8) as i32,
                                               n: 16 })
                })
                .collect::<Result<Vec<_>, _>>()?;
            for (_, rx) in rxs {
                rx.recv()??;
            }
        }
        // mixed 1–16 img requests all at once
        _ => {
            let rxs = (1..=16usize)
                .map(|n| {
                    router.submit(GenRequest { class: (n % 8) as i32, n })
                })
                .collect::<Result<Vec<_>, _>>()?;
            for (_, rx) in rxs {
                rx.recv()??;
            }
        }
    }
    Ok(router.shutdown())
}

/// Ladder-vs-fixed comparison on a mock backend (no artifacts needed):
/// padded-slot waste and p95 latency at trickle / steady / burst load.
fn adaptive_batching_bench() -> anyhow::Result<()> {
    println!(
        "\nadaptive batching (mock backend, 1 ms/slot, linger 2 ms): \
         ladder [1,2,4,8,16] vs fixed [16]"
    );
    let linger = Duration::from_millis(2);
    let ladder = vec![1usize, 2, 4, 8, 16];
    let fixed = vec![16usize];
    let mut report: Vec<(String, Json)> = Vec::new();
    for scenario in ["trickle", "steady", "burst"] {
        let mut padded = Vec::new();
        for (label, rungs) in
            [("fixed ", fixed.clone()), ("ladder", ladder.clone())]
        {
            let stats = drive_scenario(rungs, linger, scenario)?;
            let tag = label.trim();
            report.push((format!("{scenario}_{tag}_padded_slots"),
                         Json::Num(stats.padded_slots as f64)));
            report.push((format!("{scenario}_{tag}_p95_s"),
                         Json::Num(stats.latency_p95_s)));
            println!(
                "  {scenario:<8} {label}: {:>3} batches  {:>4} images  \
                 {:>4} padded  fill {:>3.0}%  p50 {:.3}s  p95 {:.3}s",
                stats.batches, stats.images, stats.padded_slots,
                stats.batch_fill * 100.0, stats.latency_p50_s,
                stats.latency_p95_s
            );
            for r in &stats.rungs {
                println!(
                    "           rung {:>3}: {:>3} batches  {:>4} images  \
                     {:>4} padded  fill {:>3.0}%",
                    r.rung, r.batches, r.images, r.padded_slots,
                    r.fill() * 100.0
                );
            }
            padded.push(stats.padded_slots);
        }
        if scenario == "trickle" {
            // the regression gate behind the whole feature: trickle
            // traffic on the ladder must waste strictly fewer slots
            anyhow::ensure!(
                padded[1] < padded[0],
                "trickle: ladder padded {} >= fixed padded {}",
                padded[1], padded[0]
            );
            println!(
                "  trickle padded slots: fixed {} -> ladder {}",
                padded[0], padded[1]
            );
        }
    }
    common::write_bench_section(
        "BENCH_serve.json",
        "batching",
        report.iter().map(|(k, v)| (k.as_str(), v.clone())).collect(),
    )?;
    Ok(())
}

// ---- step reuse: δ=0 byte-equality + throughput gates ------------------

/// FNV-1a over the f32 bit patterns — the image hash both equality
/// gates compare.
fn hash_f32(v: &[f32]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for x in v {
        for b in x.to_bits().to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
    }
    h
}

/// [`GenBackend`] over the device-free reuse trajectory
/// ([`reuse::simulate`]), so the `reuse_hits`-in-`ServerStats` gate
/// runs without PJRT or AOT artifacts: same policy, fused math and
/// counter plumbing as the real sampler backend.
struct ReuseSimBackend {
    sched: DdpmSchedule,
    groups: TimeGroups,
    drift: Vec<f32>,
    delta: f64,
    il: usize,
    rng: Rng,
    reuse: (u64, u64, u64),
}

impl GenBackend for ReuseSimBackend {
    fn rungs(&self) -> Vec<usize> {
        vec![2]
    }
    fn img_len(&self) -> usize {
        self.il
    }
    fn generate(&mut self, labels: &[i32]) -> anyhow::Result<Vec<f32>> {
        let (x, st) = reuse::simulate(
            &self.sched, &self.groups, &self.drift, self.delta,
            labels.len() * self.il, &mut self.rng,
            |x, t, _g| {
                x.iter()
                    .map(|v| (v * 0.9 + t as f32 * 1e-3).sin())
                    .collect()
            },
        );
        self.reuse.0 += st.reuse_hits as u64;
        self.reuse.1 += st.steps_skipped as u64;
        self.reuse.2 += st.uploads_saved as u64;
        Ok(x)
    }
    fn reuse_counters(&self) -> (u64, u64, u64) {
        self.reuse
    }
}

/// The step-reuse acceptance gates (device-free, so they run on every
/// CI push): δ=0 must hash-match the plain per-step loop exactly, the
/// default-δ synthetic pipeline must strictly beat the no-reuse
/// baseline in img/s with `reuse_hits > 0`, and the counters must
/// surface in `ServerStats` through the router. Writes the
/// `step_reuse` section of `BENCH_sample.json`.
fn step_reuse_bench() -> anyhow::Result<()> {
    let t_sample = 100usize;
    let sched = DdpmSchedule::new(250, 1e-4, 0.02, t_sample);
    let groups = TimeGroups::new(250, 10);
    let drift = reuse::drift_from_schedule(&sched, &groups);
    let delta = tq_dit::util::config::RunConfig::default().reuse_delta;
    let il = 16 * 16 * 3;
    println!(
        "\nstep reuse (synthetic forward, T={t_sample}, G=10, \
         default δ={delta}):"
    );

    // gate 1: δ=0 is byte-identical to the plain per-step reverse loop
    let eps_of = |x: &[f32], t: usize| -> Vec<f32> {
        x.iter().map(|v| (v * 0.9 + t as f32 * 1e-3).sin()).collect()
    };
    let mut rng_a = Rng::new(99);
    let (img0, st0) = reuse::simulate(
        &sched, &groups, &drift, 0.0, il, &mut rng_a,
        |x, t, _g| eps_of(x, t),
    );
    let mut rng_b = Rng::new(99);
    let mut plain = rng_b.normal_vec(il);
    for i in 0..sched.len() {
        let eps = eps_of(&plain, sched.steps[i]);
        let noise = if i + 1 == sched.len() {
            None
        } else {
            Some(rng_b.normal_vec(il))
        };
        sched.reverse_step(i, &mut plain, &eps, noise.as_deref());
    }
    for v in plain.iter_mut() {
        *v = v.clamp(-1.5, 1.5);
    }
    let hash_equal = hash_f32(&img0) == hash_f32(&plain);
    println!(
        "  δ=0: hash {:016x} vs plain {:016x} ({})",
        hash_f32(&img0), hash_f32(&plain),
        if hash_equal { "identical" } else { "DIVERGED" }
    );
    anyhow::ensure!(hash_equal,
                    "δ=0 reuse trajectory is not byte-identical to the \
                     plain sampler loop");
    anyhow::ensure!(st0.reuse_hits == 0 && st0.steps_skipped == 0,
                    "δ=0 must never reuse");

    // gate 2: at the default δ the costed synthetic pipeline strictly
    // beats the no-reuse baseline (each skipped forward saves its cost)
    let fwd_cost = Duration::from_micros(800);
    let n_imgs = 4usize;
    let mut run_mode = |d: f64| -> (f64, u64, u64) {
        let mut hits = 0u64;
        let mut steps = 0u64;
        let t0 = std::time::Instant::now();
        for i in 0..n_imgs {
            let mut rng = Rng::new(1000 + i as u64);
            let (_, st) = reuse::simulate(
                &sched, &groups, &drift, d, il, &mut rng,
                |x, t, _g| {
                    std::thread::sleep(fwd_cost);
                    eps_of(x, t)
                },
            );
            hits += st.reuse_hits as u64;
            steps += (st.reuse_hits + sched.len() - st.steps_skipped)
                as u64;
        }
        (t0.elapsed().as_secs_f64(), hits, steps)
    };
    let (base_s, base_hits, _) = run_mode(0.0);
    let (reuse_s, reuse_hits, _) = run_mode(delta);
    let base_ips = n_imgs as f64 / base_s.max(1e-9);
    let reuse_ips = n_imgs as f64 / reuse_s.max(1e-9);
    let reuse_rate =
        reuse_hits as f64 / (n_imgs * sched.len()) as f64;
    println!(
        "  baseline δ=0: {base_ips:.2} img/s  ({:.3} ms/step)",
        1e3 * base_s / (n_imgs * sched.len()) as f64
    );
    println!(
        "  default δ={delta}: {reuse_ips:.2} img/s  ({:.3} ms/step, \
         reuse rate {:.0}%)",
        1e3 * reuse_s / (n_imgs * sched.len()) as f64,
        reuse_rate * 100.0
    );
    anyhow::ensure!(base_hits == 0, "baseline must not reuse");
    anyhow::ensure!(reuse_hits > 0,
                    "default δ={delta} produced zero reuse hits");
    anyhow::ensure!(
        reuse_ips > base_ips,
        "step reuse did not beat the baseline: {reuse_ips:.2} <= \
         {base_ips:.2} img/s"
    );

    // gate 3: the counters surface in ServerStats through the router
    let sched2 = sched.clone();
    let groups2 = groups.clone();
    let drift2 = drift.clone();
    let body: Arc<WorkerBody> =
        Arc::new(move |h: WorkerHandle| -> anyhow::Result<()> {
            let mut b = ReuseSimBackend {
                sched: sched2.clone(),
                groups: groups2.clone(),
                drift: drift2.clone(),
                delta,
                il,
                rng: Rng::new(7),
                reuse: (0, 0, 0),
            };
            h.serve(&mut b)
        });
    let router = Router::start(
        RouterOpts { workers: 1, ..RouterOpts::default() },
        body,
    );
    let mut rxs = Vec::new();
    for i in 0..3usize {
        rxs.push(router.submit(GenRequest { class: i as i32, n: 2 })?);
    }
    for (_, rx) in rxs {
        rx.recv()??;
    }
    let stats = router.shutdown();
    println!(
        "  server stats: {} reuse hit(s), {} forward(s) skipped, \
         {} upload(s) saved",
        stats.reuse_hits, stats.steps_skipped, stats.uploads_saved
    );
    anyhow::ensure!(stats.reuse_hits > 0,
                    "reuse_hits did not surface in ServerStats");
    anyhow::ensure!(stats.reuse_hits == stats.steps_skipped,
                    "counter mismatch: {} hits vs {} skipped",
                    stats.reuse_hits, stats.steps_skipped);

    common::write_bench_section("BENCH_sample.json", "step_reuse", vec![
        ("img_per_s_baseline", Json::Num(base_ips)),
        ("img_per_s_reuse", Json::Num(reuse_ips)),
        ("per_step_ms_baseline",
         Json::Num(1e3 * base_s / (n_imgs * sched.len()) as f64)),
        ("per_step_ms_reuse",
         Json::Num(1e3 * reuse_s / (n_imgs * sched.len()) as f64)),
        ("reuse_rate", Json::Num(reuse_rate)),
        ("speedup", Json::Num(reuse_ips / base_ips.max(1e-9))),
        ("hash_equal_delta0", Json::Bool(hash_equal)),
        ("server_reuse_hits", Json::Num(stats.reuse_hits as f64)),
    ])?;
    println!("  -> δ=0 byte-identical; reuse beats baseline");
    Ok(())
}

// ---- cross-node loopback: 2 shard nodes, one killed mid-load ----------

/// A loopback shard node over a [`ShapedBackend`] router, bound to an
/// explicit address (`127.0.0.1:0` picks a port; the flap section
/// re-binds a known one after killing its node).
fn shaped_node_on(listen: &str, rungs: Vec<usize>, il: usize,
                  cost: Duration)
                  -> anyhow::Result<(NodeServer, String)> {
    let body: Arc<WorkerBody> =
        Arc::new(move |h: WorkerHandle| -> anyhow::Result<()> {
            let mut b = ShapedBackend {
                rungs: rungs.clone(),
                il,
                cost_per_slot: cost,
            };
            h.serve(&mut b)
        });
    let router = Router::start(
        RouterOpts { workers: 1, ..RouterOpts::default() },
        body,
    );
    let node =
        NodeServer::start(Box::new(router), listen, net_node_opts())?;
    let addr = node.addr().to_string();
    Ok((node, addr))
}

/// A loopback shard node over a [`ShapedBackend`] router.
fn shaped_node(rungs: Vec<usize>, il: usize, cost: Duration)
               -> anyhow::Result<(NodeServer, String)> {
    shaped_node_on("127.0.0.1:0", rungs, il, cost)
}

/// The acceptance gate for the net layer: 2 loopback shard nodes under
/// concurrent client load, one partitioned mid-flight. Every request
/// must complete on the surviving shard or fail with a typed
/// `ServeError` — zero hangs — and slot conservation
/// (`enqueued == dispatched + purged + pending`) must hold both on the
/// cluster aggregate and on the per-node shutdown stats summed.
/// Returns the scorecard for `BENCH_serve.json`.
fn cluster_loopback_bench() -> anyhow::Result<ServeMetrics> {
    println!(
        "\ncross-node loopback (2 mock shard nodes, 5 ms/slot, kill one \
         at 40 ms):"
    );
    let rungs = vec![1usize, 2, 4, 8];
    let cost = Duration::from_millis(5);
    let (node_a, addr_a) = shaped_node(rungs.clone(), 4, cost)?;
    let (node_b, addr_b) = shaped_node(rungs, 4, cost)?;
    // generous timeout: the kill is detected via the severed
    // connection (instant), and a tight timeout would let CI
    // scheduling stalls kill the healthy survivor too. Reconnects are
    // off (1 h) — this section is about losing a node *permanently*;
    // the flap section below covers revival.
    let opts = ClusterOpts {
        health: HealthPolicy {
            heartbeat: Duration::from_millis(25),
            timeout: Duration::from_secs(5),
            ..HealthPolicy::default()
        },
        reconnect: Duration::from_secs(3600),
        ..net_cluster_opts()
    };
    let t_conn = std::time::Instant::now();
    let cluster = Cluster::connect(&[addr_a, addr_b], opts)?;
    let cold_start_ms = 1e3 * t_conn.elapsed().as_secs_f64();

    let clients = 4usize;
    let per_client = 8usize;
    let completed = AtomicUsize::new(0);
    let typed_failures = AtomicUsize::new(0);
    let hangs = AtomicUsize::new(0);
    let t0 = std::time::Instant::now();
    std::thread::scope(|s| {
        // the partition: node A falls off the network mid-load
        let node_a = &node_a;
        s.spawn(move || {
            std::thread::sleep(Duration::from_millis(40));
            node_a.sever_connections();
        });
        for c in 0..clients {
            let cluster = &cluster;
            let completed = &completed;
            let typed_failures = &typed_failures;
            let hangs = &hangs;
            s.spawn(move || {
                for i in 0..per_client {
                    let n = 1 + (c * 3 + i) % 8;
                    let class = ((c + i) % 8) as i32;
                    match cluster.submit(GenRequest { class, n }) {
                        Ok((_, rx)) => match rx
                            .recv_timeout(Duration::from_secs(30))
                        {
                            Ok(Ok(_)) => {
                                completed.fetch_add(1, Ordering::Relaxed);
                            }
                            Ok(Err(_)) => {
                                typed_failures
                                    .fetch_add(1, Ordering::Relaxed);
                            }
                            Err(_) => {
                                hangs.fetch_add(1, Ordering::Relaxed);
                            }
                        },
                        Err(_) => {
                            typed_failures.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                }
            });
        }
    });
    let wall = t0.elapsed().as_secs_f64();
    let agg = cluster.shutdown();
    let stats_a = node_a.shutdown();
    let stats_b = node_b.shutdown();

    let total = clients * per_client;
    let completed = completed.load(Ordering::Relaxed);
    let typed_failures = typed_failures.load(Ordering::Relaxed);
    let hangs = hangs.load(Ordering::Relaxed);
    println!(
        "  {total} requests in {wall:.2}s: {completed} completed, \
         {typed_failures} typed failures, {hangs} hangs"
    );
    println!(
        "  cluster: {} re-queued, {} node(s) lost, p50 {:.3}s p95 {:.3}s",
        agg.requeued, agg.nodes_lost, agg.latency_p50_s, agg.latency_p95_s
    );
    println!(
        "  node A (killed): {} slots enqueued, {} dispatched, {} purged; \
         node B: {} enqueued, {} dispatched",
        stats_a.enqueued, stats_a.dispatched, stats_a.purged,
        stats_b.enqueued, stats_b.dispatched
    );

    anyhow::ensure!(hangs == 0, "{hangs} request(s) hung");
    anyhow::ensure!(
        completed + typed_failures == total,
        "requests unaccounted for: {completed} + {typed_failures} != \
         {total}"
    );
    anyhow::ensure!(agg.nodes_lost == 1,
                    "expected exactly the killed node lost, got {}",
                    agg.nodes_lost);
    anyhow::ensure!(agg.requeued >= 1,
                    "the killed node held no in-flight work");
    anyhow::ensure!(stats_b.requests > 0, "survivor served nothing");
    // conservation across the cluster: on the aggregate (surviving
    // shards) and on the per-node shutdown stats summed (both shards,
    // including the killed one, which drained after the partition)
    anyhow::ensure!(
        agg.enqueued == agg.dispatched + agg.purged + agg.pending,
        "cluster aggregate conservation broke: {} != {} + {} + {}",
        agg.enqueued, agg.dispatched, agg.purged, agg.pending
    );
    let mut summed = stats_a.clone();
    summed.absorb(&stats_b);
    anyhow::ensure!(
        summed.enqueued
            == summed.dispatched + summed.purged + summed.pending,
        "summed per-node conservation broke: {} != {} + {} + {}",
        summed.enqueued, summed.dispatched, summed.purged, summed.pending
    );
    println!("  -> all requests accounted for; conservation holds");
    Ok(ServeMetrics {
        img_per_s: agg.images as f64 / wall.max(1e-9),
        latency_p95_s: agg.latency_p95_s,
        padded_slots: summed.padded_slots,
        batch_fill: summed.batch_fill,
        cold_start_ms,
    })
}

// ---- control-plane liveness: ~10 MiB responses, zero false deaths -----

/// Backend whose pixels vary, so each 8-image response serializes to
/// roughly 10 MiB of JSON — the data plane stays saturated for whole
/// seconds while the liveness verdict must not waver.
struct BigBackend {
    il: usize,
}

impl GenBackend for BigBackend {
    fn rungs(&self) -> Vec<usize> {
        vec![8]
    }
    fn img_len(&self) -> usize {
        self.il
    }
    fn generate(&mut self, labels: &[i32]) -> anyhow::Result<Vec<f32>> {
        Ok((0..labels.len() * self.il)
            .map(|i| (i % 9973) as f32 * 1.07e-3)
            .collect())
    }
}

/// The headline-bug gate: a shard streaming ≥ 8 MiB responses under
/// sustained load, with a liveness deadline far below one response's
/// transfer+parse time. Pre-isolation, the pong queued behind the
/// response frames on the shared connection and the busy node was
/// declared dead; with the dedicated control connection (and chunked
/// data frames) the run must end with **zero** node deaths.
fn cluster_liveness_bench() -> anyhow::Result<()> {
    println!(
        "\ncontrol-plane isolation (1 shard node, ~10 MiB responses, \
         600 ms liveness deadline):"
    );
    let il = 131_072usize; // 8 imgs x 128k varied pixels ≈ 10 MiB JSON
    let body: Arc<WorkerBody> =
        Arc::new(move |h: WorkerHandle| -> anyhow::Result<()> {
            let mut b = BigBackend { il };
            h.serve(&mut b)
        });
    let router = Router::start(
        RouterOpts { workers: 1, ..RouterOpts::default() },
        body,
    );
    let node = NodeServer::start(Box::new(router), "127.0.0.1:0",
                                 net_node_opts())?;
    let addr = node.addr().to_string();
    let cluster = Cluster::connect(
        &[addr],
        ClusterOpts {
            health: HealthPolicy {
                heartbeat: Duration::from_millis(25),
                timeout: Duration::from_millis(600),
                ..HealthPolicy::default()
            },
            reconnect: Duration::from_secs(3600),
            ..net_cluster_opts()
        },
    )?;
    let n_req = 3usize;
    let t0 = std::time::Instant::now();
    let mut rxs = Vec::new();
    for i in 0..n_req {
        rxs.push(cluster.submit(GenRequest { class: i as i32, n: 8 })?);
    }
    let mut bytes_est = 0usize;
    for (_, rx) in rxs {
        let resp = rx
            .recv_timeout(Duration::from_secs(120))
            .map_err(|_| anyhow::anyhow!("big-response request hung"))??;
        // ~11 JSON bytes per varied f32 pixel
        bytes_est += resp.images.len() * 11;
    }
    let wall = t0.elapsed().as_secs_f64();
    let agg = cluster.shutdown();
    println!(
        "  {n_req} requests (~{} MiB of response JSON) in {wall:.2}s: \
         {} node death(s), p95 {:.3}s",
        bytes_est >> 20, agg.nodes_lost, agg.latency_p95_s
    );
    anyhow::ensure!(
        agg.nodes_lost == 0,
        "busy-but-healthy node falsely declared dead {} time(s)",
        agg.nodes_lost
    );
    anyhow::ensure!(agg.failed_requests == 0,
                    "{} request(s) failed on a healthy node",
                    agg.failed_requests);
    node.shutdown();
    println!("  -> zero false node-deaths under multi-MiB streaming");
    Ok(())
}

// ---- elasticity: kill a node, restart it, demand re-admission ----------

/// Kill-then-restart: node A dies mid-load (its in-flight work
/// re-queues onto B), a new node process comes up on the same address,
/// and the *same* frontend must re-admit it and hand it new
/// placements — while the slot-conservation identity keeps holding
/// across the flap.
fn cluster_flap_bench() -> anyhow::Result<()> {
    println!(
        "\nelasticity (kill node A mid-load, restart it, demand \
         re-admission):"
    );
    let rungs = vec![1usize, 2, 4];
    let cost = Duration::from_millis(5);
    let (node_a, addr_a) = shaped_node(rungs.clone(), 4, cost)?;
    let (node_b, addr_b) = shaped_node(rungs.clone(), 4, cost)?;
    let cluster = Cluster::connect(
        &[addr_a.clone(), addr_b],
        ClusterOpts {
            health: HealthPolicy {
                heartbeat: Duration::from_millis(25),
                timeout: Duration::from_secs(5),
                readmit_pongs: 3,
            },
            reconnect: Duration::from_millis(100),
            ..net_cluster_opts()
        },
    )?;

    // phase 1: load both shards, then kill A with work in flight
    let mut rxs = Vec::new();
    for i in 0..12usize {
        let n = 1 + i % 4;
        rxs.push((i, cluster.submit(GenRequest {
            class: (i % 8) as i32,
            n,
        })?));
    }
    std::thread::sleep(Duration::from_millis(30));
    node_a.shutdown(); // full node death: listener gone too
    let mut completed = 0usize;
    for (_, (_, rx)) in rxs {
        match rx.recv_timeout(Duration::from_secs(30)) {
            Ok(Ok(_)) => completed += 1,
            Ok(Err(e)) => anyhow::bail!("request failed across the \
                                         kill: {e}"),
            Err(_) => anyhow::bail!("request hung across the kill"),
        }
    }
    println!("  phase 1: {completed}/12 completed across the kill \
              (A's in-flight re-queued onto B)");

    // phase 2: restart A on the same address; the frontend must
    // re-admit it without being restarted itself
    let node_a2 = {
        let deadline = std::time::Instant::now()
            + Duration::from_secs(10);
        loop {
            match shaped_node_on(&addr_a, rungs.clone(), 4, cost) {
                Ok((node, _)) => break node,
                Err(e) => {
                    anyhow::ensure!(
                        std::time::Instant::now() < deadline,
                        "could not re-bind node A's address: {e:#}"
                    );
                    std::thread::sleep(Duration::from_millis(50));
                }
            }
        }
    };
    let deadline =
        std::time::Instant::now() + Duration::from_secs(20);
    while cluster.live_shards() < 2 {
        anyhow::ensure!(
            std::time::Instant::now() < deadline,
            "restarted node was not re-admitted within 20 s \
             ({} serving shard(s))",
            cluster.live_shards()
        );
        std::thread::sleep(Duration::from_millis(10));
    }
    println!("  phase 2: restarted node re-admitted \
              (probation pongs answered)");

    // phase 3: new load must reach the re-admitted shard
    let mut rxs = Vec::new();
    for i in 0..16usize {
        let n = 1 + i % 4;
        rxs.push(cluster.submit(GenRequest {
            class: (i % 8) as i32,
            n,
        })?);
    }
    for (_, rx) in rxs {
        match rx.recv_timeout(Duration::from_secs(30)) {
            Ok(Ok(_)) => {}
            Ok(Err(e)) => anyhow::bail!("post-readmission request \
                                         failed: {e}"),
            Err(_) => anyhow::bail!("post-readmission request hung"),
        }
    }
    let agg = cluster.shutdown();
    let stats_a2 = node_a2.shutdown();
    let stats_b = node_b.shutdown();
    println!(
        "  phase 3: restarted A served {} request(s), B {} — \
         {} lost / {} re-admitted over the flap",
        stats_a2.requests, stats_b.requests, agg.nodes_lost,
        agg.nodes_readmitted
    );
    anyhow::ensure!(agg.nodes_lost == 1,
                    "expected exactly the killed node lost, got {}",
                    agg.nodes_lost);
    anyhow::ensure!(agg.nodes_readmitted == 1,
                    "restarted node was not counted re-admitted");
    anyhow::ensure!(stats_a2.requests > 0,
                    "re-admitted node never received a placement");
    anyhow::ensure!(
        agg.enqueued == agg.dispatched + agg.purged + agg.pending,
        "conservation broke across the flap: {} != {} + {} + {}",
        agg.enqueued, agg.dispatched, agg.purged, agg.pending
    );
    println!("  -> node flap healed in place; conservation holds");
    Ok(())
}

// ---- connection capacity: many idle clients, bounded threads ----------

/// The C10k-class smoke gate: one shard node holding `target` idle
/// loopback connections while still serving a multiplexed client, with
/// process thread count O(workers) in reactor mode. The threaded
/// transport necessarily spends one handler thread per connection, so
/// its target is token-sized — the asymmetry *is* the measurement.
/// Returns the max concurrent connections held.
fn connection_count_bench() -> anyhow::Result<usize> {
    let target: usize = if reactor_mode() { 1024 } else { 48 };
    println!(
        "\nconnection capacity ({target} idle loopback clients on one \
         node):"
    );
    raise_nofile_limit(8192);
    let before = process_thread_count().unwrap_or(0);
    let body: Arc<WorkerBody> =
        Arc::new(move |h: WorkerHandle| -> anyhow::Result<()> {
            let mut b = ShapedBackend {
                rungs: vec![1, 2, 4],
                il: 4,
                cost_per_slot: Duration::from_millis(1),
            };
            h.serve(&mut b)
        });
    let router = Router::start(
        RouterOpts { workers: 1, ..RouterOpts::default() },
        body,
    );
    let node = NodeServer::start(Box::new(router), "127.0.0.1:0",
                                 net_node_opts())?;
    let addr = node.addr().to_string();
    let mut idle = Vec::with_capacity(target);
    for i in 0..target {
        idle.push(std::net::TcpStream::connect(&addr).map_err(|e| {
            anyhow::anyhow!("connection {i}/{target} refused: {e}")
        })?);
    }
    // the node must keep serving with every idle connection held open
    let client = NetClient::connect(&addr, NetClientOpts::default())?;
    let (_, rx) = client
        .submit(GenRequest { class: 3, n: 2 })
        .map_err(|e| anyhow::anyhow!("submit under load: {e}"))?;
    rx.recv_timeout(Duration::from_secs(30))
        .map_err(|_| {
            anyhow::anyhow!(
                "request hung under {target} idle connections")
        })?
        .map_err(|e| anyhow::anyhow!("request failed under load: {e}"))?;
    let during = process_thread_count().unwrap_or(0);
    let held = idle.len() + 1;
    println!(
        "  {held} connections held, threads {before} -> {during}, \
         service alive"
    );
    if reactor_mode() {
        anyhow::ensure!(
            during < before + 50,
            "thread count grew O(connections): {before} -> {during}"
        );
        println!("  -> O(workers) threads at {held} connections");
    }
    drop(idle);
    client.shutdown();
    node.shutdown();
    Ok(held)
}

// ---- observability: live /metrics scrape + tracing overhead ------------

/// The live-metrics gate (reactor mode; the threaded transport has no
/// metrics listener): drive load through a metrics-enabled node,
/// scrape `GET /metrics` while the service is busy, and hold the
/// scraped p95 gauge to the shutdown `ServerStats` within the
/// histogram's bucket error.
fn metrics_scrape_bench() -> anyhow::Result<()> {
    use std::io::{Read as _, Write as _};
    use tq_dit::obs::{hist, metrics};
    if !reactor_mode() {
        println!(
            "\nlive /metrics scrape: skipped (threaded transport has \
             no metrics listener)"
        );
        return Ok(());
    }
    println!("\nlive /metrics scrape (reactor node, shaped load):");
    let body: Arc<WorkerBody> =
        Arc::new(move |h: WorkerHandle| -> anyhow::Result<()> {
            let mut b = ShapedBackend {
                rungs: vec![1, 2, 4],
                il: 4,
                cost_per_slot: Duration::from_millis(5),
            };
            h.serve(&mut b)
        });
    let router = Router::start(
        RouterOpts { workers: 1, ..RouterOpts::default() },
        body,
    );
    let node_opts = NodeOpts {
        metrics_addr: Some("127.0.0.1:0".parse().unwrap()),
        ..net_node_opts()
    };
    let node =
        NodeServer::start(Box::new(router), "127.0.0.1:0", node_opts)?;
    let addr = node.addr().to_string();
    let maddr = node
        .metrics_addr()
        .ok_or_else(|| anyhow::anyhow!("metrics listener not bound"))?;
    let scrape = || -> anyhow::Result<String> {
        let mut h = std::net::TcpStream::connect(maddr)?;
        h.set_read_timeout(Some(Duration::from_secs(10)))?;
        h.write_all(b"GET /metrics HTTP/1.1\r\nHost: bench\r\n\r\n")?;
        let mut text = String::new();
        h.read_to_string(&mut text)?;
        anyhow::ensure!(
            text.starts_with("HTTP/1.1 200 OK\r\n"),
            "scrape failed: {}",
            text.lines().next().unwrap_or("")
        );
        Ok(text.split("\r\n\r\n").nth(1).unwrap_or("").to_string())
    };

    let client = NetClient::connect(&addr, NetClientOpts::default())?;
    let mut rxs = Vec::new();
    for i in 0..24usize {
        rxs.push(client.submit(GenRequest {
            class: (i % 8) as i32,
            n: 1 + i % 4,
        })?);
    }
    // mid-load: the endpoint must answer while the data plane works
    let mid = metrics::parse_exposition(&scrape()?);
    anyhow::ensure!(
        mid.contains_key("tqdit_requests_total"),
        "mid-load scrape missing tqdit_requests_total"
    );
    for (_, rx) in rxs {
        rx.recv_timeout(Duration::from_secs(30))
            .map_err(|_| anyhow::anyhow!("request hung mid-scrape"))??;
    }
    // drained: the scraped histogram is the same one shutdown reports
    let series = metrics::parse_exposition(&scrape()?);
    let p95_key = "tqdit_request_latency_quantile_seconds{q=\"0.95\"}";
    let p95_live = *series
        .get(p95_key)
        .ok_or_else(|| anyhow::anyhow!("scrape missing {p95_key}"))?;
    let count_live = *series
        .get("tqdit_request_latency_seconds_count")
        .unwrap_or(&0.0);
    client.shutdown();
    let stats = node.shutdown();
    println!(
        "  live: {count_live:.0} request(s) in histogram, p95 \
         {p95_live:.4}s; shutdown p95 {:.4}s",
        stats.latency_p95_s
    );
    anyhow::ensure!(
        count_live == stats.latency.count() as f64,
        "live histogram count {count_live} != shutdown count {}",
        stats.latency.count()
    );
    let tol = hist::QUANTILE_REL_ERROR * stats.latency_p95_s.max(1e-9)
        + 1e-9;
    anyhow::ensure!(
        (p95_live - stats.latency_p95_s).abs() <= tol,
        "live p95 {p95_live} drifted from shutdown p95 {} beyond \
         bucket error {tol}",
        stats.latency_p95_s
    );
    println!("  -> live scrape matches shutdown stats within bucket \
              error");
    Ok(())
}

/// Tracing cost at the router layer: the identical burst workload with
/// the span ring disabled and enabled. Off is the shipping default, so
/// it anchors the throughput numbers; on must stay within a generous
/// bound (1 ms/slot compute dominates the span writes). Writes the
/// `tracing_overhead` section of `BENCH_serve.json`.
fn tracing_overhead_bench() -> anyhow::Result<()> {
    use tq_dit::obs::trace;
    println!("\ntracing overhead (router burst, 1 ms/slot):");
    trace::enable(trace::DEFAULT_CAPACITY);
    trace::set_enabled(false);
    let run = || -> anyhow::Result<f64> {
        let t0 = std::time::Instant::now();
        let stats = drive_scenario(
            vec![1, 2, 4, 8, 16],
            Duration::from_millis(2),
            "burst",
        )?;
        Ok(stats.images as f64 / t0.elapsed().as_secs_f64().max(1e-9))
    };
    // best-of-two per mode smooths CI scheduling noise
    let off = run()?.max(run()?);
    trace::set_enabled(true);
    let on = run()?.max(run()?);
    trace::set_enabled(false);
    let spans = trace::snapshot().len();
    let overhead_pct = 100.0 * (off / on.max(1e-9) - 1.0);
    println!(
        "  tracing off: {off:.1} img/s   on: {on:.1} img/s   overhead \
         {overhead_pct:+.1}%   ({spans} span(s) recorded)"
    );
    anyhow::ensure!(spans > 0, "tracing on recorded no spans");
    anyhow::ensure!(
        on * 2.0 > off,
        "tracing on halved throughput: {on:.1} vs {off:.1} img/s"
    );
    common::write_bench_section(
        "BENCH_serve.json",
        "tracing_overhead",
        vec![
            ("img_per_s_tracing_off", Json::Num(off)),
            ("img_per_s_tracing_on", Json::Num(on)),
            ("overhead_pct", Json::Num(overhead_pct)),
            ("spans_recorded", Json::Num(spans as f64)),
        ],
    )?;
    Ok(())
}
