//! Fig. 6 regenerator: sample grids (FP / PTQ4DiT / TQ-DiT at W8A8 and
//! W6A6) written as PPM files, plus per-grid pixel statistics.

#[path = "common.rs"]
mod common;

use tq_dit::coordinator::pipeline::{Method, Pipeline};
use tq_dit::coordinator::QuantConfig;
use tq_dit::metrics::images::write_grid_ppm;
use tq_dit::util::rng::Rng;

fn stats(label: &str, imgs: &[f32], fp: &[f32]) {
    let mse: f64 = imgs.iter().zip(fp)
        .map(|(a, b)| ((a - b) as f64).powi(2)).sum::<f64>()
        / imgs.len() as f64;
    // edge energy: mean |dx| — a cheap sharpness proxy
    let sharp: f64 = imgs.windows(2).map(|w| (w[1] - w[0]).abs() as f64)
        .sum::<f64>() / imgs.len() as f64;
    println!("{label:<28} pixel-MSE vs FP {mse:>10.6}  sharpness {sharp:.4}");
}

fn main() -> anyhow::Result<()> {
    let mut cfg = common::bench_config();
    common::banner("Fig. 6: qualitative sample grids", &cfg);
    let out = std::env::var("TQDIT_BENCH_OUT").unwrap_or_else(|_| ".".into());
    let (rows, cols) = (4usize, 8usize);
    let n = rows * cols;

    let mut fp_imgs = Vec::new();
    for (w, a) in [(8u32, 8u32), (6, 6)] {
        cfg.wbits = w;
        cfg.abits = a;
        let pipe = Pipeline::new(cfg.clone())?;
        let m = pipe.rt.manifest.model.clone();
        if fp_imgs.is_empty() {
            let fp = QuantConfig::fp(pipe.groups.clone());
            fp_imgs = pipe.sample_grid(&fp, n, cfg.seed ^ 0x9b1d)?;
            let p = std::path::Path::new(&out).join("fig6_fp.ppm");
            write_grid_ppm(&p, &fp_imgs, m.img_size, m.img_size, rows,
                           cols)?;
            println!("wrote {}", p.display());
            stats("FP", &fp_imgs, &fp_imgs);
        }
        for method in [Method::Ptq4Dit, Method::TqDit] {
            let mut rng = Rng::new(cfg.seed ^ 0x5eed);
            let (qc, _) = pipe.calibrate(method, &mut rng)?;
            let imgs = pipe.sample_grid(&qc, n, cfg.seed ^ 0x9b1d)?;
            let p = std::path::Path::new(&out).join(format!(
                "fig6_{}_w{w}a{a}.ppm", method.name()));
            write_grid_ppm(&p, &imgs, m.img_size, m.img_size, rows, cols)?;
            println!("wrote {}", p.display());
            stats(&format!("{} W{w}A{a}", method.name()), &imgs, &fp_imgs);
        }
    }
    println!("\npaper shape: TQ-DiT grids stay closer to FP (lower \
              pixel-MSE, sharpness preserved) especially at W6A6.");
    Ok(())
}
