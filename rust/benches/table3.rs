//! Table III regenerator: W6A6 ablation — Baseline (uniform+MSE) →
//! +HO → +HO+MRQ → +HO+MRQ+TGQ (full TQ-DiT).

#[path = "common.rs"]
mod common;

use tq_dit::coordinator::pipeline::{Method, Pipeline};
use tq_dit::coordinator::QuantConfig;
use tq_dit::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let mut cfg = common::bench_config();
    cfg.wbits = 6;
    cfg.abits = 6;
    common::banner("Table III: component ablation @ W6A6", &cfg);
    println!("{:<24} {:>9} {:>9} {:>8}", "config", "FID", "sFID", "IS");

    let mut pipe = Pipeline::new(cfg.clone())?;
    let fp = QuantConfig::fp(pipe.groups.clone());
    let r = pipe.evaluate(&fp, cfg.eval_images, cfg.seed ^ 0xe7a1)?;
    println!("{:<24} {:>9.3} {:>9.3} {:>8.3}", "FP", r.fid, r.sfid,
             r.is_score);

    for (label, ho, mrq, tgq) in [
        ("Baseline", false, false, false),
        ("+ HO", true, false, false),
        ("+ HO + MRQ", true, true, false),
        ("+ HO + MRQ + TGQ", true, true, true),
    ] {
        pipe.cfg.use_ho = ho;
        pipe.cfg.use_mrq = mrq;
        pipe.cfg.use_tgq = tgq;
        let mut rng = Rng::new(cfg.seed ^ 0x5eed);
        let (qc, _) = pipe.calibrate(Method::TqDit, &mut rng)?;
        let row = pipe.evaluate(&qc, cfg.eval_images, cfg.seed ^ 0xe7a1)?;
        println!("{:<24} {:>9.3} {:>9.3} {:>8.3}", label, row.fid, row.sfid,
                 row.is_score);
    }
    println!("\npaper shape: monotone FID improvement 28.86 → 22.47 → \
              9.31 → 8.58 (ours should order the same way).");
    Ok(())
}
