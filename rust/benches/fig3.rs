//! Fig. 3 regenerator: max post-softmax channel magnitude vs timestep —
//! the temporal variance that motivates TGQ.

#[path = "common.rs"]
mod common;

use tq_dit::coordinator::pipeline::Pipeline;
use tq_dit::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let mut cfg = common::bench_config();
    cfg.calib_per_group = cfg.calib_per_group.max(8);
    common::banner("Fig. 3: max |post-softmax| vs timestep", &cfg);
    let pipe = Pipeline::new(cfg.clone())?;
    let mut rng = Rng::new(cfg.seed);
    let (_, ev) = pipe.grouped_evidence(&mut rng)?;

    // bucket by time group for a stable console plot
    let g = pipe.groups.clone();
    let mut sums = vec![0.0f64; g.groups];
    let mut mins = vec![f64::INFINITY; g.groups];
    let mut maxs = vec![0.0f64; g.groups];
    let mut counts = vec![0usize; g.groups];
    for &(t, m) in &ev.softmax_max_by_t {
        let gi = g.group_of(t);
        sums[gi] += m as f64;
        mins[gi] = mins[gi].min(m as f64);
        maxs[gi] = maxs[gi].max(m as f64);
        counts[gi] += 1;
    }
    println!("\n{:>12} {:>8} {:>8} {:>8}", "t-range", "mean", "min", "max");
    let mut means = Vec::new();
    for i in 0..g.groups {
        let (lo, hi) = g.range_of(i);
        let mean = sums[i] / counts[i].max(1) as f64;
        means.push(mean);
        let bar = "#".repeat((mean * 60.0).round() as usize);
        println!("{:>5}..{:<5} {mean:>8.3} {:>8.3} {:>8.3}  {bar}", lo, hi,
                 mins[i], maxs[i]);
    }
    let spread = means.iter().fold(0.0f64, |a, &b| a.max(b))
        / means.iter().fold(f64::INFINITY, |a, &b| a.min(b));
    println!("\nmax/min group-mean ratio: {spread:.2}x (paper Fig. 3: \
              strong variance across timesteps → one Δ per trajectory \
              cannot fit all groups)");
    Ok(())
}
