//! Fig. 1 regenerator: the headline FID/IS bar chart — every method at
//! W8A8 and W6A6 (T=250 in the paper; bench-sized T by default), as
//! console bars.

#[path = "common.rs"]
mod common;

use tq_dit::coordinator::pipeline::{Method, Pipeline};
use tq_dit::coordinator::QuantConfig;
use tq_dit::util::rng::Rng;

fn bar(v: f64, max: f64, width: usize) -> String {
    let n = ((v / max.max(1e-9)) * width as f64).round() as usize;
    "#".repeat(n.min(width))
}

fn main() -> anyhow::Result<()> {
    let mut cfg = common::bench_config();
    common::banner("Fig. 1: headline FID/IS comparison", &cfg);

    let mut rows: Vec<(String, f64, f64)> = Vec::new();
    {
        let pipe = Pipeline::new(cfg.clone())?;
        let fp = QuantConfig::fp(pipe.groups.clone());
        let r = pipe.evaluate(&fp, cfg.eval_images, cfg.seed ^ 0xe7a1)?;
        rows.push(("FP".into(), r.fid, r.is_score));
    }
    for (w, a) in [(8u32, 8u32), (6, 6)] {
        cfg.wbits = w;
        cfg.abits = a;
        let pipe = Pipeline::new(cfg.clone())?;
        for method in Method::ALL_QUANT {
            let mut rng = Rng::new(cfg.seed ^ 0x5eed);
            let (qc, _) = pipe.calibrate(method, &mut rng)?;
            let r = pipe.evaluate(&qc, cfg.eval_images, cfg.seed ^ 0xe7a1)?;
            rows.push((format!("{} W{w}A{a}", method.name()), r.fid,
                       r.is_score));
        }
    }

    let fid_max = rows.iter().map(|r| r.1).fold(0.0, f64::max);
    let is_max = rows.iter().map(|r| r.2).fold(0.0, f64::max);
    println!("\n{:<24} {:>8}  FID bars (lower better)", "method", "FID");
    for (name, fid, _) in &rows {
        println!("{name:<24} {fid:>8.3}  {}", bar(*fid, fid_max, 40));
    }
    println!("\n{:<24} {:>8}  IS bars (higher better)", "method", "IS");
    for (name, _, is) in &rows {
        println!("{name:<24} {is:>8.3}  {}", bar(*is, is_max, 40));
    }
    println!("\npaper shape: TQ-DiT bars closest to FP at both widths.");
    Ok(())
}
