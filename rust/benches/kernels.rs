//! Micro-benchmarks of the host-side hot paths: fake-quant application,
//! the HO objective, candidate search, qparams packing, and the FID
//! linear algebra. These are the L3 components the §Perf pass tunes.

#[path = "common.rs"]
mod common;

use tq_dit::quant::search::{argmin_candidates, uniform_candidates, Problem};
use tq_dit::quant::{MrqGelu, MrqSoftmax, SiteParams, UniformQ};
use tq_dit::tensor::linalg::trace_sqrt_product;
use tq_dit::tensor::Tensor;
use tq_dit::util::bench::Bench;
use tq_dit::util::rng::Rng;

fn main() {
    let b = Bench::default();
    let mut rng = Rng::new(7);

    // --- fake-quant throughput (1M elements) ---------------------------
    let data = rng.normal_vec(1 << 20);
    let uq = UniformQ::from_minmax(-3.0, 3.0, 8);
    let mut buf = data.clone();
    let r = b.run("uniform_fakequant/1M", || {
        buf.copy_from_slice(&data);
        uq.fakequant_slice(&mut buf);
    });
    println!("  -> {:.2} Gelem/s", r.per_sec(1 << 20) / 1e9);

    let ms = MrqSoftmax::new(1.0 / 1024.0, 8);
    let probs: Vec<f32> = data.iter().map(|v| (v.abs() * 0.1).min(1.0))
        .collect();
    let r = b.run("mrq_softmax_fakequant/1M", || {
        buf.copy_from_slice(&probs);
        ms.fakequant_slice(&mut buf);
    });
    println!("  -> {:.2} Gelem/s", r.per_sec(1 << 20) / 1e9);

    let mg = MrqGelu::new(0.002, 0.03, 8);
    let r = b.run("mrq_gelu_fakequant/1M", || {
        buf.copy_from_slice(&data);
        mg.fakequant_slice(&mut buf);
    });
    println!("  -> {:.2} Gelem/s", r.per_sec(1 << 20) / 1e9);

    // --- HO objective over a realistic layer problem --------------------
    let a: Vec<Tensor> = (0..12)
        .map(|_| Tensor::new(vec![64, 96], rng.normal_vec(64 * 96)))
        .collect();
    let w = Tensor::new(vec![96, 384], rng.normal_vec(96 * 384));
    let fish: Vec<Tensor> = (0..12)
        .map(|_| Tensor::new(vec![64, 384], rng.normal_vec(64 * 384)))
        .collect();
    let prob = Problem::new(a, vec![w; 12], Some(fish));
    let qa = SiteParams::Uniform(UniformQ::from_minmax(-3.0, 3.0, 8));
    let qb = SiteParams::Uniform(UniformQ::from_minmax(-0.3, 0.3, 8));
    b.run("ho_objective/fc1-style(12x64x96x384)", || {
        std::hint::black_box(prob.eval(&qa, &qb));
    });

    // --- candidate search (parallel argmin) -----------------------------
    let cands = uniform_candidates(-3.0, 3.0, 8, 48);
    b.run("argmin_candidates/48xfc1", || {
        std::hint::black_box(argmin_candidates(&cands,
                                               |c| prob.eval(c, &qb)));
    });

    // --- FID linear algebra ---------------------------------------------
    for d in [64usize, 192] {
        let mut c1 = vec![0.0f64; d * d];
        let mut c2 = vec![0.0f64; d * d];
        for i in 0..d {
            for j in 0..d {
                let v = ((i * 31 + j * 17) % 13) as f64 / 13.0;
                c1[i * d + j] += v;
                c1[j * d + i] += v;
                c2[i * d + j] += 1.0 - v;
                c2[j * d + i] += 1.0 - v;
            }
            c1[i * d + i] += d as f64;
            c2[i * d + i] += d as f64;
        }
        b.run(&format!("trace_sqrt_product/{d}d"), || {
            std::hint::black_box(trace_sqrt_product(&c1, &c2, d));
        });
    }

    // --- host matmul kernel ----------------------------------------------
    let x = Tensor::new(vec![512, 96], rng.normal_vec(512 * 96));
    let w2 = Tensor::new(vec![96, 384], rng.normal_vec(96 * 384));
    let r = b.run("host_matmul/512x96x384", || {
        std::hint::black_box(x.matmul(&w2));
    });
    let flops = 2.0 * 512.0 * 96.0 * 384.0;
    println!("  -> {:.2} GFLOP/s", flops / r.mean_s / 1e9);
}
