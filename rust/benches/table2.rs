//! Table II regenerator: same grid as Table I at T=100 (respaced
//! sampler over the 250-step training schedule).

#[path = "common.rs"]
mod common;

use tq_dit::coordinator::pipeline::{Method, Pipeline};
use tq_dit::coordinator::QuantConfig;
use tq_dit::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let mut cfg = common::bench_config();
    cfg.timesteps = if common::full() { 100 } else { 25 };
    common::banner("Table II: T=100 (respaced) quality comparison", &cfg);

    for (w, a) in [(8u32, 8u32), (6, 6)] {
        cfg.wbits = w;
        cfg.abits = a;
        println!("\n-- W{w}A{a} --");
        println!("{:<22} {:>9} {:>9} {:>8} {:>9}", "method", "FID", "sFID",
                 "IS", "calib(s)");
        let pipe = Pipeline::new(cfg.clone())?;
        let fp = QuantConfig::fp(pipe.groups.clone());
        let r = pipe.evaluate(&fp, cfg.eval_images, cfg.seed ^ 0xe7a1)?;
        println!("{:<22} {:>9.3} {:>9.3} {:>8.3} {:>9}", "FP (32/32)",
                 r.fid, r.sfid, r.is_score, "-");
        for method in Method::ALL_QUANT {
            let mut rng = Rng::new(cfg.seed ^ 0x5eed);
            let (qc, cost) = pipe.calibrate(method, &mut rng)?;
            let row = pipe.evaluate(&qc, cfg.eval_images,
                                    cfg.seed ^ 0xe7a1)?;
            println!("{:<22} {:>9.3} {:>9.3} {:>8.3} {:>9.1}",
                     method.name(), row.fid, row.sfid, row.is_score,
                     cost.wall_s);
        }
    }
    println!("\npaper shape: same ordering as Table I; respaced sampler \
              (fewer steps) amplifies quantization error at W6A6.");
    Ok(())
}
