//! Table IV regenerator: calibration efficiency — wall-clock + memory
//! of the TQ-DiT calibrator vs the PTQ4DiT-style calibrator.

#[path = "common.rs"]
mod common;

use tq_dit::coordinator::pipeline::{Method, Pipeline};
use tq_dit::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let cfg = common::bench_config();
    common::banner("Table IV: calibration cost", &cfg);

    let pipe = Pipeline::new(cfg.clone())?;
    let mut costs = Vec::new();
    for method in [Method::Ptq4Dit, Method::TqDit] {
        let mut rng = Rng::new(cfg.seed ^ 0x5eed);
        let (_, cost) = pipe.calibrate(method, &mut rng)?;
        cost.print(method.name());
        costs.push(cost);
    }
    let (p4, tq) = (&costs[0], &costs[1]);
    println!("\ntime reduction:   {:.1}% (paper: 89.3%)",
             100.0 * (1.0 - tq.wall_s / p4.wall_s.max(1e-9)));
    println!("memory reduction: {:.1}% (paper: 45.4%; ours uses evidence \
              bytes as the apples-to-apples proxy)",
             100.0 * (1.0 - tq.evidence_bytes as f64
                      / p4.evidence_bytes.max(1) as f64));
    Ok(())
}
