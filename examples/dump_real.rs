//! Diagnostic: dump rust-rendered synthetic images (one per class,
//! repeated) as raw f32 LE to /tmp/rust_real.bin for cross-checking
//! against the python generator/classifier.

use tq_dit::data::SynthDataset;
use tq_dit::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let ds = SynthDataset::new(16, 3, 8);
    let mut rng = Rng::new(0);
    let n = 64;
    let il = ds.image_len();
    let mut out = Vec::with_capacity(n * il);
    let mut labels = Vec::new();
    for i in 0..n {
        let k = i % 8;
        labels.push(k as u8);
        let mut img = vec![0.0f32; il];
        ds.render(k, &mut rng, &mut img);
        out.extend_from_slice(&img);
    }
    let bytes: Vec<u8> = out.iter().flat_map(|v| v.to_le_bytes()).collect();
    std::fs::write("/tmp/rust_real.bin", &bytes)?;
    std::fs::write("/tmp/rust_real_labels.bin", &labels)?;
    println!("wrote {} images", n);
    Ok(())
}
