//! Diagnostic probe (not part of the paper's deliverables): compares the
//! FP and quantized forwards on one input, and inspects the classifier's
//! behaviour on real synthetic images vs generated ones.

use tq_dit::coordinator::pipeline::{Method, Pipeline};
use tq_dit::coordinator::QuantConfig;
use tq_dit::metrics::softmax;

use tq_dit::sampler::Sampler;
use tq_dit::tensor::Tensor;
use tq_dit::util::cli::Args;
use tq_dit::util::config::RunConfig;
use tq_dit::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let mut cfg = RunConfig::from_args(&args)?;
    cfg.timesteps = 50;
    cfg.calib_per_group = 4;
    let pipe = Pipeline::new(cfg.clone())?;
    let m = pipe.rt.manifest.clone();
    let b = m.batches.sample_max();
    let il = m.model.img_size * m.model.img_size * m.model.channels;
    let mut rng = Rng::new(1);

    // --- 1. FP vs quantized forward on the same input ------------------
    let (qc, _) = pipe.calibrate(Method::TqDit, &mut rng)?;
    let x = Tensor::new(vec![b, m.model.img_size, m.model.img_size,
                             m.model.channels],
                        rng.normal_vec(b * il));
    let t = vec![25i32; b];
    let y: Vec<i32> = (0..b).map(|i| (i % 8) as i32).collect();

    let wq = pipe.weights.fakequant(&qc.weights);
    let fp_buf = pipe.rt.upload_all(&pipe.weights.tensors)?;
    let q_buf = pipe.rt.upload_all(&wq.tensors)?;
    let xb = pipe.rt.upload(&x)?;
    let tb = pipe.rt.upload_i32(&t, &[b])?;
    let yb = pipe.rt.upload_i32(&y, &[b])?;

    let mut inputs: Vec<&xla::PjRtBuffer> = fp_buf.iter().collect();
    inputs.extend([&xb, &tb, &yb]);
    let eps_fp = &pipe.rt.run_buffers("dit_fp_sample", &inputs)?[0];

    let qp = Tensor::new(vec![m.qp_len], qc.qparams_for_group(&m, 1));
    println!("qp vector head: {:?}", &qp.data[..12]);
    let qpb = pipe.rt.upload(&qp)?;
    let mut qi: Vec<&xla::PjRtBuffer> = q_buf.iter().collect();
    qi.extend([&xb, &tb, &yb, &qpb]);
    let eps_q = &pipe.rt.run_buffers("dit_quant", &qi)?[0];

    let mse = eps_fp.mse(eps_q);
    let e_norm: f64 = eps_fp.data.iter().map(|&v| (v as f64) * v as f64)
        .sum::<f64>() / eps_fp.len() as f64;
    println!("FP-vs-quant eps MSE = {mse:.6e} (fp power {e_norm:.4})");

    // all-bypass must reproduce FP exactly
    let byp = Tensor::new(vec![m.qp_len], vec![0.0; m.qp_len]);
    let bypb = pipe.rt.upload(&byp)?;
    let mut bi: Vec<&xla::PjRtBuffer> = fp_buf.iter().collect();
    bi.extend([&xb, &tb, &yb, &bypb]);
    let eps_byp = &pipe.rt.run_buffers("dit_quant", &bi)?[0];
    println!("FP-vs-bypass eps MSE = {:.6e}", eps_fp.mse(eps_byp));

    // --- 2. classifier on REAL vs GENERATED images ----------------------
    let ds = &pipe.ds;
    let mut imgs = vec![0.0f32; m.batches.feat * il];
    let mut labels = vec![0usize; m.batches.feat];
    for i in 0..m.batches.feat {
        labels[i] = i % 8;
        let mut tmp = vec![0.0f32; il];
        ds.render(labels[i], &mut rng, &mut tmp);
        imgs[i * il..(i + 1) * il].copy_from_slice(&tmp);
    }
    let (_, cw) = m.load_metric_weights()?;
    let cbufs = pipe.rt.upload_all(&cw)?;
    let imgb = pipe.rt.upload(&Tensor::new(
        vec![m.batches.feat, m.model.img_size, m.model.img_size,
             m.model.channels], imgs))?;
    let mut cin: Vec<&xla::PjRtBuffer> = cbufs.iter().collect();
    cin.push(&imgb);
    let logits = &pipe.rt.run_buffers("classifier", &cin)?[0];
    let nc = logits.cols();
    let mut correct = 0;
    for i in 0..m.batches.feat {
        let p = softmax(&logits.data[i * nc..(i + 1) * nc]);
        let am = p.iter().enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).unwrap().0;
        if am == labels[i] { correct += 1; }
    }
    println!("classifier acc on REAL images: {}/{}", correct, m.batches.feat);

    // generated images per class
    let fp_cfg = QuantConfig::fp(pipe.groups.clone());
    let sampler = Sampler::new(&pipe.rt, &pipe.weights, fp_cfg,
                               cfg.timesteps)?;
    let glabels: Vec<i32> = (0..b).map(|i| (i % 8) as i32).collect();
    let (gen, _) = sampler.sample(&glabels, &mut rng)?;
    println!("gen img stats: min {:.3} max {:.3} mean {:.3}",
             gen.iter().fold(f32::INFINITY, |a, &v| a.min(v)),
             gen.iter().fold(f32::NEG_INFINITY, |a, &v| a.max(v)),
             gen.iter().sum::<f32>() / gen.len() as f32);
    let mut padded = gen.clone();
    padded.resize(m.batches.feat * il, 0.0);
    let genb = pipe.rt.upload(&Tensor::new(
        vec![m.batches.feat, m.model.img_size, m.model.img_size,
             m.model.channels], padded))?;
    let mut gin: Vec<&xla::PjRtBuffer> = cbufs.iter().collect();
    gin.push(&genb);
    let logits = &pipe.rt.run_buffers("classifier", &gin)?[0];
    let mut hits = 0;
    for i in 0..b {
        let p = softmax(&logits.data[i * nc..(i + 1) * nc]);
        let am = p.iter().enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).unwrap().0;
        print!("{am}");
        if am == glabels[i] as usize { hits += 1; }
    }
    println!("  <- argmax classes of generated (labels {glabels:?})");
    println!("generated matched {}/{}", hits, b);
    Ok(())
}
