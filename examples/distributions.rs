//! Fig. 2 / Fig. 3 reproduction — the two activation pathologies that
//! motivate MRQ and TGQ:
//!
//! * Fig. 2a/2b: histograms of post-softmax and post-GELU values across
//!   DiT blocks (written as CSV: center,density).
//! * Fig. 3: max |post-softmax| channel magnitude per timestep (CSV:
//!   timestep,max) — the temporal variance TGQ addresses.
//!
//! Run: cargo run --release --example distributions -- --out-dir /tmp

use std::io::Write;
use std::path::Path;

use tq_dit::coordinator::pipeline::Pipeline;
use tq_dit::util::cli::Args;
use tq_dit::util::config::RunConfig;
use tq_dit::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let mut cfg = RunConfig::from_args(&args)?;
    cfg.calib_per_group = args.usize("calib-per-group", 16)?;
    let out_dir = args.str_or("out-dir", ".").to_string();

    let pipe = Pipeline::new(cfg.clone())?;
    let mut rng = Rng::new(cfg.seed);
    let (_, ev) = pipe.grouped_evidence(&mut rng)?;

    // Fig. 2a: post-softmax histogram
    let p = Path::new(&out_dir).join("fig2a_softmax_hist.csv");
    let mut f = std::fs::File::create(&p)?;
    writeln!(f, "center,density")?;
    for (c, d) in ev.softmax_hist.densities() {
        writeln!(f, "{c},{d}")?;
    }
    println!("fig2a -> {} ({} samples)", p.display(), ev.softmax_hist.count);

    // Fig. 2b: post-GELU histogram
    let p = Path::new(&out_dir).join("fig2b_gelu_hist.csv");
    let mut f = std::fs::File::create(&p)?;
    writeln!(f, "center,density")?;
    for (c, d) in ev.gelu_hist.densities() {
        writeln!(f, "{c},{d}")?;
    }
    println!("fig2b -> {} ({} samples)", p.display(), ev.gelu_hist.count);

    // Fig. 3: per-timestep max post-softmax magnitude
    let p = Path::new(&out_dir).join("fig3_softmax_max_by_t.csv");
    let mut rows = ev.softmax_max_by_t.clone();
    rows.sort_by_key(|r| r.0);
    let mut f = std::fs::File::create(&p)?;
    writeln!(f, "timestep,max_softmax")?;
    for (t, m) in &rows {
        writeln!(f, "{t},{m}")?;
    }
    println!("fig3  -> {} ({} points)", p.display(), rows.len());

    // console summary: the asymmetry + temporal-variance facts the paper
    // reads off these figures.
    let sm = &ev.softmax_hist;
    let below = sm.bins[..sm.bins.len() / 8].iter().sum::<u64>() as f64;
    println!("\npost-softmax: {:.1}% of mass below 1/8 of the range \
              (paper: concentrated near 0)",
             100.0 * below / sm.count.max(1) as f64);
    let neg = ev.gelu_hist.underflow as f64
        + ev.gelu_hist.bins.iter().enumerate()
            .filter(|(i, _)| {
                let w = (ev.gelu_hist.hi - ev.gelu_hist.lo)
                    / ev.gelu_hist.bins.len() as f32;
                ev.gelu_hist.lo + w * (*i as f32 + 0.5) < 0.0
            })
            .map(|(_, &c)| c)
            .sum::<u64>() as f64;
    println!("post-GELU: {:.1}% of values negative (paper: negative skew, \
              bounded tail)",
             100.0 * neg / ev.gelu_hist.count.max(1) as f64);
    let lo_t: Vec<f32> = rows.iter().filter(|r| r.0 < 50)
        .map(|r| r.1).collect();
    let hi_t: Vec<f32> = rows.iter().filter(|r| r.0 >= 200)
        .map(|r| r.1).collect();
    let mean = |v: &[f32]| v.iter().sum::<f32>() / v.len().max(1) as f32;
    println!("max|softmax|: mean {:.3} at t<50 vs {:.3} at t>=200 \
              (paper Fig. 3: strong timestep dependence)",
             mean(&lo_t), mean(&hi_t));
    Ok(())
}
