//! Loss-curve E2E driver: continues training the DiT *from rust* by
//! driving the AOT `train_step` artifact (fwd + bwd + Adam fused in one
//! XLA computation) — no python anywhere on the path.
//!
//! Demonstrates that the full training loop composes through the PJRT
//! runtime: rust generates the synthetic batches, owns the optimizer
//! state, and logs the DDPM loss curve.
//!
//! Run: cargo run --release --example train_from_rust -- --steps 60

use tq_dit::coordinator::pipeline::Pipeline;
use tq_dit::sched::DdpmSchedule;
use tq_dit::tensor::Tensor;
use tq_dit::util::cli::Args;
use tq_dit::util::config::RunConfig;
use tq_dit::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let cfg = RunConfig::from_args(&args)?;
    let steps = args.usize("steps", 60)?;

    let pipe = Pipeline::new(cfg.clone())?;
    let m = pipe.rt.manifest.clone();
    let tb = m.batches.train;
    let img = m.model.img_size;
    let il = img * img * m.model.channels;
    let npar = m.n_params();
    let mut rng = Rng::new(cfg.seed ^ 0x7a11);

    // optimizer state: params from weights.bin, m/v zeroed
    let mut params = pipe.weights.tensors.clone();
    let mut mstate: Vec<Tensor> = params.iter()
        .map(|t| Tensor::zeros(t.shape.clone())).collect();
    let mut vstate = mstate.clone();

    // training-schedule ᾱ (runtime input — see aot.py §4 note)
    let d = &m.diffusion;
    let sched = DdpmSchedule::new(d.train_steps, d.beta_start, d.beta_end,
                                  d.train_steps);
    let abar: Vec<f32> = sched.train_alpha_bars.iter()
        .map(|&v| v as f32).collect();
    let abar_t = Tensor::new(vec![d.train_steps], abar);

    println!("== train-from-rust: {} steps @ batch {} ==", steps, tb);
    let t0 = std::time::Instant::now();
    let mut first_loss = None;
    let mut last_loss = 0.0f32;
    for step in 0..steps {
        // synthetic batch (same generator the model was trained on)
        let (x0, y) = pipe.ds.sample_batch(tb, &mut rng);
        let t: Vec<i32> = (0..tb)
            .map(|_| rng.below(d.train_steps) as i32).collect();
        let eps = rng.normal_vec(tb * il);

        // assemble inputs: params*3, step, x0, t, y, eps, abar
        let mut bufs = Vec::with_capacity(3 * npar + 6);
        for t_ in params.iter().chain(&mstate).chain(&vstate) {
            bufs.push(pipe.rt.upload(t_)?);
        }
        bufs.push(pipe.rt.upload_i32(&[step as i32], &[])?);
        bufs.push(pipe.rt.upload(&Tensor::new(
            vec![tb, img, img, m.model.channels], x0))?);
        bufs.push(pipe.rt.upload_i32(&t, &[tb])?);
        bufs.push(pipe.rt.upload_i32(&y, &[tb])?);
        bufs.push(pipe.rt.upload(&Tensor::new(
            vec![tb, img, img, m.model.channels], eps))?);
        bufs.push(pipe.rt.upload(&abar_t)?);
        let inputs: Vec<&xla::PjRtBuffer> = bufs.iter().collect();
        let outs = pipe.rt.run_buffers("train_step", &inputs)?;

        // outputs: params*3 then loss
        for (dst, src) in params.iter_mut().zip(&outs[..npar]) {
            *dst = src.clone();
        }
        for (dst, src) in mstate.iter_mut().zip(&outs[npar..2 * npar]) {
            *dst = src.clone();
        }
        for (dst, src) in vstate.iter_mut().zip(&outs[2 * npar..3 * npar]) {
            *dst = src.clone();
        }
        last_loss = outs[3 * npar].data[0];
        if first_loss.is_none() {
            first_loss = Some(last_loss);
        }
        if step % 10 == 0 || step + 1 == steps {
            println!("step {step:4}  loss {last_loss:.4}");
        }
    }
    let dt = t0.elapsed().as_secs_f64();
    println!("\n{} steps in {:.1}s ({:.2} steps/s); loss {:.4} -> {:.4}",
             steps, dt, steps as f64 / dt, first_loss.unwrap(), last_loss);
    println!("(already-converged weights: expect the curve to hover near \
              its floor rather than drop)");
    Ok(())
}
