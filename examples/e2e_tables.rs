//! E2E driver — regenerates Table I (T=250) / Table II (T=100): the
//! full calibrate → quantize → sample → FID/sFID/IS flow for FP + all
//! four calibrators at the requested bit-width.
//!
//! This is the repository's required end-to-end validation: every layer
//! composes (synthetic data → PJRT capture → host-side HO/MRQ/TGQ search
//! → quantized PJRT sampling → metric artifacts), and the table rows it
//! prints are the ones EXPERIMENTS.md records.
//!
//! Run (paper-sized):  cargo run --release --example e2e_tables -- \
//!                       --timesteps 250 --wbits 8 --abits 8
//! Quick smoke:        ... -- --timesteps 50 --eval-images 64 \
//!                       --calib-per-group 8

use tq_dit::coordinator::pipeline::{Method, Pipeline};
use tq_dit::coordinator::QuantConfig;
use tq_dit::util::cli::Args;
use tq_dit::util::config::RunConfig;
use tq_dit::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let cfg = RunConfig::from_args(&args)?;
    let methods: Vec<Method> = args
        .str_or("methods", "q-diffusion,ptqd,ptq4dit,tq-dit")
        .split(',')
        .filter_map(Method::parse)
        .collect();

    println!("== Table reproduction: T={} W{}A{} ({} eval images) ==",
             cfg.timesteps, cfg.wbits, cfg.abits, cfg.eval_images);
    println!("{:<22} {:>9} {:>9} {:>8} {:>9}", "method", "FID", "sFID",
             "IS", "calib(s)");

    let pipe = Pipeline::new(cfg.clone())?;

    // FP reference row
    let fp = QuantConfig::fp(pipe.groups.clone());
    let fp_row = pipe.evaluate(&fp, cfg.eval_images, cfg.seed ^ 0xe7a1)?;
    println!("{:<22} {:>9.3} {:>9.3} {:>8.3} {:>9}", "FP (32/32)",
             fp_row.fid, fp_row.sfid, fp_row.is_score, "-");

    for method in methods {
        let mut rng = Rng::new(cfg.seed ^ 0x5eed);
        let (qc, cost) = pipe.calibrate(method, &mut rng)?;
        let row = pipe.evaluate(&qc, cfg.eval_images, cfg.seed ^ 0xe7a1)?;
        println!("{:<22} {:>9.3} {:>9.3} {:>8.3} {:>9.1}",
                 format!("{} ({}/{})", method.name(), cfg.wbits, cfg.abits),
                 row.fid, row.sfid, row.is_score, cost.wall_s);
    }

    println!("\npaper shape (Table I/II): every method ≈ FP at W8A8 with \
              TQ-DiT closest; at W6A6 baselines degrade hard and TQ-DiT \
              degrades least.");
    Ok(())
}
