//! Fig. 6 reproduction — sample grids for visual comparison:
//! TQ-DiT vs PTQ4DiT at the requested bit-width, plus an FP reference
//! grid, written as PPM images.
//!
//! Run: cargo run --release --example sample_grid -- --wbits 8 --abits 8
//! Outputs fig6_<method>_w<k>a<k>.ppm in --out-dir (default .).

use std::path::Path;

use tq_dit::coordinator::pipeline::{Method, Pipeline};
use tq_dit::coordinator::QuantConfig;
use tq_dit::metrics::images::write_grid_ppm;
use tq_dit::util::cli::Args;
use tq_dit::util::config::RunConfig;
use tq_dit::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let cfg = RunConfig::from_args(&args)?;
    let out_dir = args.str_or("out-dir", ".").to_string();
    let rows = args.usize("rows", 4)?;
    let cols = args.usize("cols", 8)?;
    let n = rows * cols;

    let pipe = Pipeline::new(cfg.clone())?;
    let m = &pipe.rt.manifest.model;

    // FP reference grid
    let fp = QuantConfig::fp(pipe.groups.clone());
    let imgs = pipe.sample_grid(&fp, n, cfg.seed ^ 0x9b1d)?;
    let p = Path::new(&out_dir).join("fig6_fp.ppm");
    write_grid_ppm(&p, &imgs, m.img_size, m.img_size, rows, cols)?;
    println!("wrote {}", p.display());

    for method in [Method::Ptq4Dit, Method::TqDit] {
        let mut rng = Rng::new(cfg.seed ^ 0x5eed);
        let (qc, _) = pipe.calibrate(method, &mut rng)?;
        let imgs = pipe.sample_grid(&qc, n, cfg.seed ^ 0x9b1d)?;
        let p = Path::new(&out_dir).join(format!(
            "fig6_{}_w{}a{}.ppm", method.name(), cfg.wbits, cfg.abits));
        write_grid_ppm(&p, &imgs, m.img_size, m.img_size, rows, cols)?;
        println!("wrote {}", p.display());
    }
    println!("\npaper shape (Fig. 6): TQ-DiT grids stay sharp at W8A8 and \
              preserve detail at W6A6 where PTQ4DiT degrades.");
    Ok(())
}
