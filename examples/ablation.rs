//! Table III reproduction — ablation at W6A6: Baseline → +HO → +HO+MRQ
//! → +HO+MRQ+TGQ (the full TQ-DiT), each calibrated and evaluated.
//!
//! Run: cargo run --release --example ablation -- --wbits 6 --abits 6
//! Quick: ... -- --timesteps 50 --eval-images 64 --calib-per-group 8

use tq_dit::coordinator::pipeline::{Method, Pipeline};
use tq_dit::coordinator::QuantConfig;
use tq_dit::util::cli::Args;
use tq_dit::util::config::RunConfig;
use tq_dit::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let mut cfg = RunConfig::from_args(&args)?;
    if args.get("wbits").is_none() {
        cfg.wbits = 6; // Table III is the W6A6 study
    }
    if args.get("abits").is_none() {
        cfg.abits = 6;
    }

    println!("== Table III ablation (W{}A{}, T={}) ==", cfg.wbits,
             cfg.abits, cfg.timesteps);
    println!("{:<24} {:>9} {:>9} {:>8}", "config", "FID", "sFID", "IS");

    let mut pipe = Pipeline::new(cfg.clone())?;
    let fp = QuantConfig::fp(pipe.groups.clone());
    let fp_row = pipe.evaluate(&fp, cfg.eval_images, cfg.seed ^ 0xe7a1)?;
    println!("{:<24} {:>9.3} {:>9.3} {:>8.3}", "FP", fp_row.fid,
             fp_row.sfid, fp_row.is_score);

    // (label, ho, mrq, tgq); Baseline == uniform+MSE == Q-Diffusion row.
    let rows = [
        ("Baseline", false, false, false),
        ("+ HO", true, false, false),
        ("+ HO + MRQ", true, true, false),
        ("+ HO + MRQ + TGQ", true, true, true),
    ];
    for (label, ho, mrq, tgq) in rows {
        pipe.cfg.use_ho = ho;
        pipe.cfg.use_mrq = mrq;
        pipe.cfg.use_tgq = tgq;
        let mut rng = Rng::new(cfg.seed ^ 0x5eed);
        let (qc, _) = pipe.calibrate(Method::TqDit, &mut rng)?;
        let row = pipe.evaluate(&qc, cfg.eval_images, cfg.seed ^ 0xe7a1)?;
        println!("{:<24} {:>9.3} {:>9.3} {:>8.3}", label, row.fid, row.sfid,
                 row.is_score);
    }
    println!("\npaper shape: FID improves monotonically down the table.");
    Ok(())
}
