//! Quickstart: the whole TQ-DiT flow in ~40 lines.
//!
//! Loads the AOT artifacts, calibrates TQ-DiT at W8A8 with small
//! settings, samples a few images through the quantized model and
//! scores them against the full-precision baseline.
//!
//! Run with: `cargo run --release --example quickstart`

use tq_dit::coordinator::pipeline::{Method, Pipeline};
use tq_dit::coordinator::QuantConfig;
use tq_dit::util::cli::Args;
use tq_dit::util::config::RunConfig;
use tq_dit::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let mut cfg = RunConfig::from_args(&args)?;
    // quickstart-sized run: fewer sampler steps + calibration samples
    cfg.timesteps = args.usize("timesteps", 50)?;
    cfg.calib_per_group = args.usize("calib-per-group", 8)?;
    cfg.eval_images = args.usize("eval-images", 32)?;

    println!("== TQ-DiT quickstart (W{}A{}, T={}) ==", cfg.wbits, cfg.abits,
             cfg.timesteps);
    let pipe = Pipeline::new(cfg.clone())?;
    println!("model: dim={} depth={} tokens={} ({} params)",
             pipe.rt.manifest.model.dim, pipe.rt.manifest.model.depth,
             pipe.rt.manifest.model.tokens, pipe.weights.n_elements());

    // 1. full-precision reference
    let fp = QuantConfig::fp(pipe.groups.clone());
    let fp_row = pipe.evaluate(&fp, cfg.eval_images, 7)?;
    fp_row.print("FP (32/32)");

    // 2. calibrate TQ-DiT (Algorithm 1) and evaluate
    let mut rng = Rng::new(cfg.seed);
    let (qc, cost) = pipe.calibrate(Method::TqDit, &mut rng)?;
    cost.print("tq-dit");
    println!("calibrated {} sites ({} TGQ overlays, {} weight quantizers)",
             qc.sites.len(), qc.tgq.len(), qc.weights.len());
    let row = pipe.evaluate(&qc, cfg.eval_images, 7)?;
    row.print(&format!("TQ-DiT (W{}A{})", cfg.wbits, cfg.abits));

    println!("\nFID gap vs FP: {:+.3} (paper: +0.29 at W8A8, T=250)",
             row.fid - fp_row.fid);
    Ok(())
}
