//! Sharded generation-service demo: several client threads firing
//! mixed-size requests at a multi-worker server, which calibrates the
//! quantization config once, shares it across worker shards, and packs
//! the fixed-size artifact batches from one FIFO queue.
//!
//! Reports per-request latency, then the aggregate + per-worker stats
//! (throughput, fill, padding, queue depth, p50/p95 latency).
//!
//! Run: cargo run --release --example serve_demo -- \
//!        --timesteps 50 --calib-per-group 8 \
//!        --clients 3 --requests 4 --workers 2

use std::sync::atomic::{AtomicUsize, Ordering};

use tq_dit::coordinator::pipeline::Method;
use tq_dit::serve::{GenRequest, GenServer};
use tq_dit::util::cli::Args;
use tq_dit::util::config::RunConfig;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let mut cfg = RunConfig::from_args(&args)?;
    cfg.timesteps = args.usize("timesteps", 50)?;
    cfg.calib_per_group = args.usize("calib-per-group", 8)?;
    let clients = args.usize("clients", 3)?.max(1);
    let n_req = args.usize("requests", 4)?;
    let workers = args.usize("workers", 2)?.max(1);
    let method = Method::parse(args.str_or("method", "tq-dit"))
        .ok_or_else(|| anyhow::anyhow!("unknown --method"))?;

    println!(
        "== serve demo: {clients} clients x {n_req} requests via {} on \
         {workers} workers (W{}A{}, T={}) ==",
        method.name(), cfg.wbits, cfg.abits, cfg.timesteps
    );
    let server = GenServer::with_workers(cfg, method, workers);

    // mixed request sizes across classes, all clients submitting
    // concurrently against the shared handle
    let failures = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for c in 0..clients {
            let server = &server;
            let failures = &failures;
            s.spawn(move || {
                for i in 0..n_req {
                    let req = GenRequest {
                        class: ((c + i) % 8) as i32,
                        n: 1 + (c * 7 + i * 5) % 11,
                    };
                    let n = req.n;
                    match server.submit(req) {
                        Ok((id, rx)) => match rx.recv() {
                            Ok(Ok(resp)) => println!(
                                "client {c} req {i} (id {id}): {n} images \
                                 in {:.2}s ({} px)",
                                resp.latency_s, resp.images.len()
                            ),
                            Ok(Err(e)) => {
                                failures.fetch_add(1, Ordering::Relaxed);
                                eprintln!("client {c} req {i}: {e}");
                            }
                            Err(_) => {
                                failures.fetch_add(1, Ordering::Relaxed);
                                eprintln!(
                                    "client {c} req {i}: channel closed"
                                );
                            }
                        },
                        Err(e) => {
                            failures.fetch_add(1, Ordering::Relaxed);
                            eprintln!("client {c} req {i}: rejected: {e}");
                        }
                    }
                }
            });
        }
    });

    let stats = server.shutdown();
    stats.print();
    let failed = failures.load(Ordering::Relaxed);
    if failed > 0 {
        anyhow::bail!("{failed} request(s) failed");
    }
    Ok(())
}
