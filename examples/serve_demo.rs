//! Sharded generation-service demo: several client threads firing
//! requests at a multi-worker server, which calibrates the quantization
//! config once, shares it across worker shards, and packs batches from
//! one FIFO queue under the deadline-aware ladder policy.
//!
//! Scenarios (`--scenario`) exercise both ends of the batch ladder:
//!
//! * `mixed`   — the classic mixed-size concurrent load (default)
//! * `trickle` — 1 image per request, sparse arrivals: small rungs
//!               keep latency low and padding near zero
//! * `burst`   — mixed 1–16 images per request, all at once: the big
//!               rungs fill while stragglers ride the small ones
//!
//! `--nodes N` runs the same load through the cross-node stack
//! instead: N loopback shard nodes (each its own GenServer behind a
//! TCP listener on 127.0.0.1) under one cluster frontend — the demo
//! client code is identical because both ends implement `Dispatch`.
//! Each shard gets a dedicated control connection (disable with
//! `--control-plane false` to see the pre-isolation topology), so a
//! node busy streaming responses is never mistaken for a dead one.
//! `--kill-node-after-ms T` partitions node 0 mid-load to show the
//! re-queue path: with a surviving node every request still completes,
//! and since node 0 keeps listening, the frontend re-dials it
//! (`--reconnect-ms`), probes it (`--readmit-pongs`) and re-admits it
//! — the demo prints the moment it is placed back in rotation.
//! `--restart-node-after-ms T` is the harsher flap: node 0 is shut
//! down entirely (listener gone) and a fresh node is started on the
//! same address T ms later; the frontend must re-admit the stranger
//! without restarting.
//!
//! Reports per-request latency, then the aggregate + per-worker +
//! per-rung stats (throughput, fill, padding, queue depth, p50/p95),
//! plus per-node stats in cluster mode.
//!
//! Run: cargo run --release --example serve_demo -- \
//!        --timesteps 50 --calib-per-group 8 \
//!        --clients 3 --requests 4 --workers 2 \
//!        --scenario trickle --linger-ms 5 --batch-ladder 1,4,16
//!      cargo run --release --example serve_demo -- \
//!        --nodes 2 --workers 1 --kill-node-after-ms 500 \
//!        --reconnect-ms 200 --readmit-pongs 2

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use tq_dit::coordinator::pipeline::Method;
use tq_dit::serve::{
    Cluster, ClusterOpts, Dispatch, GenRequest, GenServer, NodeOpts,
    NodeServer,
};
use tq_dit::util::cli::Args;
use tq_dit::util::config::RunConfig;

/// Request size + arrival spacing per scenario.
fn shape_request(scenario: &str, client: usize, i: usize)
                 -> (usize, Duration) {
    match scenario {
        // one image per request, spaced out: the ladder's small rungs
        // should carry all of it without padding
        "trickle" => (1, Duration::from_millis(30)),
        // mixed 1–16 images, no spacing: fills the big rungs
        "burst" => (1 + (client * 7 + i * 5) % 16, Duration::ZERO),
        // the classic demo load
        _ => (1 + (client * 7 + i * 5) % 11, Duration::ZERO),
    }
}

/// Local server or cluster frontend behind one dispatch surface — kept
/// as an enum (not a `Box<dyn Dispatch>`) so the fault-injection
/// thread can watch cluster-only signals like `live_shards`.
enum Service {
    Local(GenServer),
    Cluster(Cluster),
}

impl Service {
    fn dispatch(&self) -> &dyn Dispatch {
        match self {
            Service::Local(s) => s,
            Service::Cluster(c) => c,
        }
    }

    fn shutdown(self) -> tq_dit::serve::ServerStats {
        match self {
            Service::Local(s) => s.shutdown(),
            Service::Cluster(c) => c.shutdown(),
        }
    }
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let mut cfg = RunConfig::from_args(&args)?;
    cfg.timesteps = args.usize("timesteps", 50)?;
    cfg.calib_per_group = args.usize("calib-per-group", 8)?;
    let clients = args.usize("clients", 3)?.max(1);
    let n_req = args.usize("requests", 4)?;
    let workers = args.usize("workers", 2)?.max(1);
    let scenario = args.str_or("scenario", "mixed").to_string();
    if !["mixed", "trickle", "burst"].contains(&scenario.as_str()) {
        anyhow::bail!("unknown --scenario `{scenario}` \
                       (mixed|trickle|burst)");
    }
    let method = Method::parse(args.str_or("method", "tq-dit"))
        .ok_or_else(|| anyhow::anyhow!("unknown --method"))?;
    let nodes = args.usize("nodes", 0)?;
    let kill_after_ms = args.u64("kill-node-after-ms", 0)?;
    let restart_after_ms = args.u64("restart-node-after-ms", 0)?;
    if restart_after_ms > 0 && nodes == 0 {
        anyhow::bail!("--restart-node-after-ms needs --nodes N");
    }

    println!(
        "== serve demo [{scenario}]: {clients} clients x {n_req} requests \
         via {} on {workers} workers (W{}A{}, T={}, linger {} ms, \
         ladder {}) ==",
        method.name(), cfg.wbits, cfg.abits, cfg.timesteps, cfg.linger_ms,
        cfg.batch_ladder
            .as_ref()
            .map(|l| format!("{l:?}"))
            .unwrap_or_else(|| "manifest".into()),
    );
    // local or loopback-cluster topology behind one dispatch surface —
    // the client code below cannot tell them apart
    let node_handles: Mutex<Vec<NodeServer>> = Mutex::new(Vec::new());
    let mut node0_addr = String::new();
    let server: Service = if nodes > 0 {
        let mut addrs = Vec::new();
        for _ in 0..nodes {
            let gs = GenServer::with_workers(cfg.clone(), method, workers);
            let node = NodeServer::start(Box::new(gs), "127.0.0.1:0",
                                         NodeOpts::default())?;
            addrs.push(node.addr().to_string());
            node_handles.lock().unwrap().push(node);
        }
        node0_addr = addrs[0].clone();
        println!("loopback cluster: {nodes} shard node(s) at {} \
                  (control plane {})",
                 addrs.join(", "),
                 if cfg.control_plane { "isolated" } else { "shared" });
        Service::Cluster(Cluster::connect(
            &addrs, ClusterOpts::from_run_config(&cfg))?)
    } else {
        Service::Local(GenServer::with_workers(cfg.clone(), method,
                                               workers))
    };

    // all clients submitting concurrently against the shared handle
    let failures = AtomicUsize::new(0);
    std::thread::scope(|s| {
        // fault injection: partition (sever) or fully restart node 0
        // mid-load, then watch the frontend heal
        if (kill_after_ms > 0 || restart_after_ms > 0) && nodes > 0 {
            let server = &server;
            let node_handles = &node_handles;
            let node0_addr = node0_addr.clone();
            let cfg = cfg.clone();
            s.spawn(move || {
                let Service::Cluster(cluster) = server else { return };
                let delay = kill_after_ms.max(restart_after_ms);
                std::thread::sleep(Duration::from_millis(delay));
                // death detection is asynchronous, so healing is
                // observed via the re-admission counter (a transient
                // live_shards dip could be missed entirely)
                let readmitted_before = cluster.nodes_readmitted();
                if restart_after_ms > 0 {
                    // full death: drain + drop the node, listener gone
                    let node0 = node_handles.lock().unwrap().remove(0);
                    node0.shutdown();
                    eprintln!("[demo] node 0 shut down — its in-flight \
                               requests re-queue onto the survivors");
                } else {
                    if let Some(first) =
                        node_handles.lock().unwrap().first()
                    {
                        first.sever_connections();
                    }
                    eprintln!("[demo] partitioned node 0 — its \
                               in-flight requests re-queue onto the \
                               survivors");
                }
                let t_dead = Instant::now();
                if restart_after_ms > 0 {
                    // bring a fresh node up on the same address (bind
                    // can briefly race the old listener's close);
                    // bounded like the bench's rebind loop so a stolen
                    // port cannot hang the demo forever
                    let bind_deadline =
                        Instant::now() + Duration::from_secs(15);
                    loop {
                        let gs = GenServer::with_workers(cfg.clone(),
                                                         method,
                                                         workers);
                        match NodeServer::start(Box::new(gs),
                                                &node0_addr,
                                                NodeOpts::default()) {
                            Ok(node) => {
                                eprintln!("[demo] restarted node 0 on \
                                           {node0_addr}");
                                node_handles.lock().unwrap().push(node);
                                break;
                            }
                            Err(e) if Instant::now() > bind_deadline => {
                                eprintln!("[demo] giving up re-binding \
                                           {node0_addr}: {e}");
                                return;
                            }
                            Err(e) => {
                                eprintln!("[demo] re-bind pending: {e}");
                                std::thread::sleep(
                                    Duration::from_millis(100));
                            }
                        }
                    }
                }
                // the frontend heals on its own: reconnect → probation
                // → K pongs → re-admitted into placement
                let deadline = Instant::now() + Duration::from_secs(30);
                while cluster.nodes_readmitted() == readmitted_before {
                    if Instant::now() > deadline {
                        eprintln!("[demo] node 0 NOT re-admitted \
                                   within 30 s");
                        return;
                    }
                    std::thread::sleep(Duration::from_millis(10));
                }
                eprintln!("[demo] node 0 re-admitted {} ms after its \
                           death — no frontend restart",
                          t_dead.elapsed().as_millis());
            });
        }
        for c in 0..clients {
            let server = &server;
            let failures = &failures;
            let scenario = scenario.as_str();
            s.spawn(move || {
                for i in 0..n_req {
                    let (n, gap) = shape_request(scenario, c, i);
                    if !gap.is_zero() {
                        std::thread::sleep(gap);
                    }
                    let req = GenRequest { class: ((c + i) % 8) as i32, n };
                    match server.dispatch().submit(req) {
                        Ok((id, rx)) => match rx.recv() {
                            Ok(Ok(resp)) => println!(
                                "client {c} req {i} (id {id}): {n} images \
                                 in {:.2}s ({} px)",
                                resp.latency_s, resp.images.len()
                            ),
                            Ok(Err(e)) => {
                                failures.fetch_add(1, Ordering::Relaxed);
                                eprintln!("client {c} req {i}: {e}");
                            }
                            Err(_) => {
                                failures.fetch_add(1, Ordering::Relaxed);
                                eprintln!(
                                    "client {c} req {i}: channel closed"
                                );
                            }
                        },
                        Err(e) => {
                            failures.fetch_add(1, Ordering::Relaxed);
                            eprintln!("client {c} req {i}: rejected: {e}");
                        }
                    }
                }
            });
        }
    });

    let stats = server.shutdown();
    stats.print();
    for (i, node) in node_handles.into_inner().unwrap()
        .into_iter()
        .enumerate()
    {
        println!("-- node {i} --");
        node.shutdown().print();
    }
    let failed = failures.load(Ordering::Relaxed);
    if failed > 0 {
        anyhow::bail!("{failed} request(s) failed");
    }
    Ok(())
}
