//! Generation-service demo: the dynamic batcher + worker loop serving
//! mixed-size requests through the quantized sampler, reporting
//! per-request latency and aggregate throughput.
//!
//! Run: cargo run --release --example serve_demo -- \
//!        --timesteps 50 --calib-per-group 8 --requests 6

use tq_dit::coordinator::pipeline::Method;
use tq_dit::serve::{GenRequest, GenServer};
use tq_dit::util::cli::Args;
use tq_dit::util::config::RunConfig;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let mut cfg = RunConfig::from_args(&args)?;
    cfg.timesteps = args.usize("timesteps", 50);
    cfg.calib_per_group = args.usize("calib-per-group", 8);
    let n_req = args.usize("requests", 6);
    let method = Method::parse(args.str_or("method", "tq-dit"))
        .expect("unknown --method");

    println!("== serve demo: {} requests via {} (W{}A{}, T={}) ==", n_req,
             method.name(), cfg.wbits, cfg.abits, cfg.timesteps);
    let server = GenServer::start(cfg, method);

    // mixed request sizes across classes, all in flight at once
    let mut handles = Vec::new();
    for i in 0..n_req {
        let req = GenRequest { class: (i % 8) as i32, n: 3 + (i * 5) % 11 };
        println!("submit req {i}: class {} x{}", req.class, req.n);
        handles.push((i, req.n, server.submit(req)));
    }
    for (i, n, (id, rx)) in handles {
        let resp = rx.recv()?;
        assert_eq!(resp.id, id);
        println!("req {i}: {n} images in {:.2}s ({} px)", resp.latency_s,
                 resp.images.len());
    }

    let stats = server.shutdown();
    stats.print();
    Ok(())
}
