//! Table IV reproduction — calibration cost: TQ-DiT vs the
//! PTQ4DiT-style calibrator on identical hardware.
//!
//! The paper reports GPU memory (GB) and GPU hours; our testbed is a
//! CPU PJRT client, so we report peak-RSS delta and wall-clock of the
//! calibration phase (capture + search) plus the structural counters
//! that explain the gap (calibration-set size, evidence bytes,
//! objective evaluations).
//!
//! Run: cargo run --release --example efficiency
//! Quick: ... -- --calib-per-group 8 --candidates 32

use tq_dit::coordinator::pipeline::{Method, Pipeline};
use tq_dit::util::cli::Args;
use tq_dit::util::config::RunConfig;
use tq_dit::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let cfg = RunConfig::from_args(&args)?;
    println!("== Table IV: calibration efficiency (W{}A{}) ==", cfg.wbits,
             cfg.abits);

    let pipe = Pipeline::new(cfg.clone())?;
    let mut results = Vec::new();
    for method in [Method::Ptq4Dit, Method::TqDit] {
        let mut rng = Rng::new(cfg.seed ^ 0x5eed);
        let (_, cost) = pipe.calibrate(method, &mut rng)?;
        cost.print(method.name());
        results.push((method, cost));
    }

    let (p4, tq) = (&results[0].1, &results[1].1);
    println!("\n{:<18} {:>12} {:>12} {:>10}", "", "PTQ4DiT", "TQ-DiT",
             "reduction");
    let mem_red = 100.0
        * (1.0 - tq.peak_rss_delta as f64 / p4.peak_rss_delta.max(1) as f64);
    let t_red = 100.0 * (1.0 - tq.wall_s / p4.wall_s.max(1e-9));
    println!("{:<18} {:>12.2} {:>12.2} {:>9.1}%", "calib time (s)",
             p4.wall_s, tq.wall_s, t_red);
    println!("{:<18} {:>12} {:>12} {:>9.1}%", "peak mem (MiB)",
             p4.peak_rss_delta / (1 << 20), tq.peak_rss_delta / (1 << 20),
             mem_red);
    println!("{:<18} {:>12} {:>12}", "evidence (MiB)",
             p4.evidence_bytes / (1 << 20), tq.evidence_bytes / (1 << 20));
    println!("{:<18} {:>12} {:>12}", "objective evals", p4.evals, tq.evals);
    println!("{:<18} {:>12} {:>12}", "capture batches", p4.capture_batches,
             tq.capture_batches);
    println!("\npaper: TQ-DiT uses 45.4% less memory and 89.3% less time \
              than PTQ4DiT.");
    Ok(())
}
