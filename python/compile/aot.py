"""AOT exporter: python runs ONCE here; rust owns everything after.

Produces in ``artifacts/``:

  dit_fp_sample.hlo.txt   FP forward, one per SAMPLE_LADDER rung (the
                          largest rung unsuffixed, smaller rungs @b{B})
  dit_fp_calib.hlo.txt    FP forward,   batch = CALIB_BATCH
  dit_quant.hlo.txt       quant forward (pallas kernels), per rung as
                          above
  dit_quant_calib.hlo.txt quant forward, CALIB_BATCH
  dit_capture.hlo.txt     FP forward + per-layer inputs + ∂L/∂z (Fisher)
  train_step.hlo.txt      fwd+bwd+Adam in one XLA computation
  feature_net.hlo.txt     FID/sFID features (weights baked in)
  classifier.hlo.txt      IS classifier (trained here, baked in)
  weights.bin             pretrained DiT weights (f32 LE, param_order)
  fid_ref.bin             reference FID/sFID gaussian stats
  manifest.json           shapes, layouts, batch sizes — rust's map

HLO *text* is the interchange format (NOT serialized protos): jax ≥ 0.5
emits 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
parser reassigns ids (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import features as feat_mod
from . import train as train_mod
from .config import (CALIB_BATCH, DIFFUSION, MODEL, SAMPLE_LADDER,
                     TRAIN_BATCH, build_layers, qparam_layout)
from .model import forward, forward_aux, layer_z_shapes, param_specs
from .qmodel import forward_quant


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def export(fn, specs, path: str) -> None:
    t0 = time.time()
    text = to_hlo_text(jax.jit(fn).lower(*specs))
    if "constant({...})" in text:
        raise RuntimeError(
            f"{path}: large constant elided by as_hlo_text — pass the "
            "offending array as a runtime parameter instead of a closure")
    with open(path, "w") as f:
        f.write(text)
    print(f"[aot] wrote {path} ({len(text)/1e6:.2f} MB, "
          f"{time.time()-t0:.1f}s)")


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--train-steps", type=int,
                    default=int(os.environ.get("TQDIT_TRAIN_STEPS", "2000")))
    ap.add_argument("--clf-steps", type=int,
                    default=int(os.environ.get("TQDIT_CLF_STEPS", "400")))
    ap.add_argument("--reuse-weights", action="store_true",
                    default=os.environ.get("TQDIT_REUSE_WEIGHTS") == "1",
                    help="skip pretraining if weights.bin already exists")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    cfg, dc = MODEL, DIFFUSION
    specs = param_specs(cfg)
    pnames = [n for n, _ in specs]
    npar = len(pnames)
    _, qp_len = qparam_layout(cfg)
    abar = jnp.asarray(train_mod.alpha_bars(dc), jnp.float32)

    # ---- 1. pretrain the scaled-down DiT --------------------------------
    wpath = os.path.join(args.out, "weights.bin")
    expected_bytes = 4 * sum(int(np.prod(s)) for _, s in specs)
    if (args.reuse_weights and os.path.exists(wpath)
            and os.path.getsize(wpath) == expected_bytes):
        print("[aot] reusing existing weights.bin (--reuse-weights)")
        raw = np.fromfile(wpath, np.float32)
        flat, off = [], 0
        for _, shape in specs:
            n = int(np.prod(shape))
            flat.append(jnp.asarray(raw[off:off + n].reshape(shape)))
            off += n
        params = train_mod.unflatten_params(flat, cfg)
    else:
        print(f"[aot] pretraining DiT ({args.train_steps} steps)...")
        params = train_mod.pretrain(cfg, dc, args.train_steps, TRAIN_BATCH)
        flat = train_mod.flatten_params(params, cfg)
        with open(wpath, "wb") as f:
            for arr in flat:
                f.write(np.asarray(arr, np.float32).tobytes())

    # ---- 2. forward artifacts -------------------------------------------
    pspecs = [f32(*shape) for _, shape in specs]

    def fp_fn(*a):
        p = dict(zip(pnames, a[:npar]))
        x, t, y = a[npar], a[npar + 1], a[npar + 2]
        return (forward(p, x, t, y, cfg),)

    def quant_fn(*a):
        p = dict(zip(pnames, a[:npar]))
        x, t, y, qp = a[npar], a[npar + 1], a[npar + 2], a[npar + 3]
        return (forward_quant(p, x, t, y, qp, cfg),)

    # sampling graphs, lowered once per ladder rung: the largest rung
    # keeps the classic unsuffixed names, smaller rungs get @b{B}
    # suffixes (rust resolves them via Manifest::sample_artifact)
    sample_artifacts = {}
    for B in SAMPLE_LADDER:
        io = [f32(B, cfg.img_size, cfg.img_size, cfg.channels),
              i32(B), i32(B)]
        # rust resolves the unsuffixed names to the *largest* rung of
        # the (sorted) ladder, so key the suffix off max(), not off
        # position — a reordered SAMPLE_LADDER must not silently ship a
        # batch-mismatched unsuffixed executable
        suffix = "" if B == max(SAMPLE_LADDER) else f"@b{B}"
        fp_name = f"dit_fp_sample{suffix}"
        q_name = f"dit_quant{suffix}"
        export(fp_fn, pspecs + io,
               os.path.join(args.out, f"{fp_name}.hlo.txt"))
        export(quant_fn, pspecs + io + [f32(qp_len)],
               os.path.join(args.out, f"{q_name}.hlo.txt"))
        sample_artifacts[fp_name] = f"{fp_name}.hlo.txt"
        sample_artifacts[q_name] = f"{q_name}.hlo.txt"

    # calibration-batch graphs (single rung)
    B = CALIB_BATCH
    io = [f32(B, cfg.img_size, cfg.img_size, cfg.channels),
          i32(B), i32(B)]
    export(fp_fn, pspecs + io,
           os.path.join(args.out, "dit_fp_calib.hlo.txt"))
    export(quant_fn, pspecs + io + [f32(qp_len)],
           os.path.join(args.out, "dit_quant_calib.hlo.txt"))

    # ---- 3. capture artifact (Fisher ingredients) ------------------------
    B = CALIB_BATCH
    zshapes = layer_z_shapes(cfg, B)
    layers = build_layers(cfg)
    cap_order = []          # (manifest name, source) after eps_pred
    for layer in layers:
        if layer.ltype == "linear":
            cap_order.append((layer.sites[0].name, ("in", layer.sites[0].name)))
        else:
            cap_order.append((layer.sites[0].name, ("in", layer.sites[0].name)))
            cap_order.append((layer.sites[1].name, ("in", layer.sites[1].name)))
        cap_order.append((layer.name + ".grad", ("grad", layer.name)))

    def capture_fn(*a):
        p = dict(zip(pnames, a[:npar]))
        x, t, y, eps_true = a[npar], a[npar + 1], a[npar + 2], a[npar + 3]
        deltas0 = {k: jnp.zeros(s, jnp.float32) for k, s in zshapes.items()}

        def loss_of(d):
            pred, _ = forward_aux(p, x, t, y, cfg, deltas=d)
            return jnp.mean((pred - eps_true) ** 2)

        grads = jax.grad(loss_of)(deltas0)
        pred, aux = forward_aux(p, x, t, y, cfg, collect=True)
        outs = [pred]
        for _, (kind, key) in cap_order:
            outs.append(aux["in"][key] if kind == "in" else grads[key])
        return tuple(outs)

    io = [f32(B, cfg.img_size, cfg.img_size, cfg.channels), i32(B), i32(B),
          f32(B, cfg.img_size, cfg.img_size, cfg.channels)]
    export(capture_fn, pspecs + io,
           os.path.join(args.out, "dit_capture.hlo.txt"))

    # ---- 4. train-step artifact ------------------------------------------
    # NOTE: everything a lowered fn closes over as a LARGE array constant
    # (>8 elements or so) is elided to `constant({...})` by as_hlo_text
    # and silently lost — so ᾱ and the metric-net weights are runtime
    # PARAMETERS, exactly like the DiT weights.
    TB = TRAIN_BATCH

    def train_fn(*a):
        p = dict(zip(pnames, a[:npar]))
        m = dict(zip(pnames, a[npar:2 * npar]))
        v = dict(zip(pnames, a[2 * npar:3 * npar]))
        step = a[3 * npar]
        x0, t, y, eps, abar_in = a[3 * npar + 1:3 * npar + 6]
        new_p, new_m, new_v, loss = train_mod.train_step(
            p, m, v, step, x0, t, y, eps, abar_in, cfg)
        return tuple([new_p[k] for k in pnames]
                     + [new_m[k] for k in pnames]
                     + [new_v[k] for k in pnames] + [loss])

    io = [i32(), f32(TB, cfg.img_size, cfg.img_size, cfg.channels),
          i32(TB), i32(TB),
          f32(TB, cfg.img_size, cfg.img_size, cfg.channels),
          f32(dc.train_steps)]
    export(train_fn, pspecs * 3 + io,
           os.path.join(args.out, "train_step.hlo.txt"))

    # ---- 5. metric networks (weights as runtime params) -------------------
    FB = feat_mod.NUM_FEAT_BATCH
    fparams = feat_mod.feature_params()
    fnames = feat_mod.FEAT_PARAM_ORDER

    def feat_fn(*a):
        fp = dict(zip(fnames, a[:len(fnames)]))
        return feat_mod.feature_net(fp, a[len(fnames)])

    fspecs = [f32(*fparams[k].shape) for k in fnames]
    export(feat_fn,
           fspecs + [f32(FB, cfg.img_size, cfg.img_size, cfg.channels)],
           os.path.join(args.out, "feature_net.hlo.txt"))

    print(f"[aot] training IS classifier ({args.clf_steps} steps)...")
    cparams, acc = feat_mod.train_classifier(cfg, steps=args.clf_steps)
    cnames = feat_mod.CLF_PARAM_ORDER

    def clf_fn(*a):
        cp = dict(zip(cnames, a[:len(cnames)]))
        return (feat_mod.classifier_logits(cp, a[len(cnames)]),)

    cspecs = [f32(*cparams[k].shape) for k in cnames]
    export(clf_fn,
           cspecs + [f32(FB, cfg.img_size, cfg.img_size, cfg.channels)],
           os.path.join(args.out, "classifier.hlo.txt"))

    with open(os.path.join(args.out, "metric_weights.bin"), "wb") as f:
        for k in fnames:
            f.write(np.asarray(fparams[k], np.float32).tobytes())
        for k in cnames:
            f.write(np.asarray(cparams[k], np.float32).tobytes())

    # ---- 6. reference FID stats -------------------------------------------
    print("[aot] computing reference FID stats...")
    mu_f, cov_f, mu_s, cov_s = feat_mod.reference_stats(cfg)
    with open(os.path.join(args.out, "fid_ref.bin"), "wb") as f:
        for arr in (mu_f, cov_f, mu_s, cov_s):
            f.write(np.asarray(arr, np.float32).tobytes())

    # ---- 7. manifest -------------------------------------------------------
    offsets, _ = qparam_layout(cfg)
    manifest = {
        "model": {
            "img_size": cfg.img_size, "channels": cfg.channels,
            "patch": cfg.patch, "dim": cfg.dim, "depth": cfg.depth,
            "heads": cfg.heads, "num_classes": cfg.num_classes,
            "mlp_ratio": cfg.mlp_ratio, "freq_dim": cfg.freq_dim,
            "tokens": cfg.tokens, "head_dim": cfg.head_dim,
            "patch_dim": cfg.patch_dim,
        },
        "diffusion": {
            "train_steps": dc.train_steps,
            "beta_start": dc.beta_start, "beta_end": dc.beta_end,
        },
        "params": [{"name": n, "shape": list(s)} for n, s in specs],
        "layers": [
            {
                "name": l.name, "ltype": l.ltype, "weight": l.weight,
                "sites": [
                    {"name": s.name, "kind": s.kind, "tgq": s.tgq,
                     "qp_offset": offsets[s.name]}
                    for s in l.sites
                ],
            }
            for l in layers
        ],
        "qp_len": qp_len,
        "batches": {"calib": CALIB_BATCH,
                    "sample": list(SAMPLE_LADDER),
                    "train": TRAIN_BATCH, "feat": FB},
        "capture_outputs": [
            {"name": name,
             "shape": list(np.shape(np.empty(
                 zshapes[src] if kind == "grad" else _in_shape(
                     src, cfg, B)))) }
            for name, (kind, src) in cap_order
        ],
        "feat_dim": feat_mod.FEAT_DIM,
        "spat_dim": feat_mod.SPAT_DIM,
        "classifier_acc": acc,
        "metric_params": {
            "feature": [{"name": k, "shape": list(fparams[k].shape)}
                        for k in fnames],
            "classifier": [{"name": k, "shape": list(cparams[k].shape)}
                           for k in cnames],
        },
        "metric_weights": "metric_weights.bin",
        "artifacts": {
            **sample_artifacts,
            "dit_fp_calib": "dit_fp_calib.hlo.txt",
            "dit_quant_calib": "dit_quant_calib.hlo.txt",
            "dit_capture": "dit_capture.hlo.txt",
            "train_step": "train_step.hlo.txt",
            "feature_net": "feature_net.hlo.txt",
            "classifier": "classifier.hlo.txt",
        },
        "weights": "weights.bin",
        "fid_ref": "fid_ref.bin",
    }
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print("[aot] manifest.json written — artifacts complete")


def _in_shape(site: str, cfg, B):
    """Shape of a captured site input tensor."""
    D, H, M = cfg.dim, cfg.heads, cfg.mlp_dim
    N, hd = cfg.tokens, cfg.head_dim
    if site == "patch_embed.x":
        return (B, N, cfg.patch_dim)
    if site == "final.x":
        return (B, N, D)
    parts = site.split(".")
    kind = parts[1] + "." + parts[2]
    table = {
        "adaln.x": (B, D),
        "qkv.x": (B, N, D),
        "qk.a": (B, H, N, hd),
        "qk.b": (B, H, N, hd),
        "av.a": (B, H, N, N),
        "av.b": (B, H, N, hd),
        "proj.x": (B, N, D),
        "fc1.x": (B, N, D),
        "fc2.x": (B, N, M),
    }
    return table[kind]


if __name__ == "__main__":
    main()
