"""Training utilities: DDPM loss, Adam, train step, build-time pretraining.

``train_step`` is also AOT-exported (``train_step.hlo.txt``) so the rust
example ``train_from_rust.rs`` can continue training the model through
PJRT with no python on the path — the loss-curve E2E driver.
"""

from __future__ import annotations

from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import data as data_mod
from .config import DiffusionConfig, ModelConfig
from .model import Params, forward, init_params, param_order

Adam = Tuple[Params, Params]   # (m, v)


# --------------------------------------------------------------------------
# diffusion schedule (mirrored in rust sched/ddpm.rs)
# --------------------------------------------------------------------------

def betas(dc: DiffusionConfig) -> np.ndarray:
    return np.linspace(dc.beta_start, dc.beta_end, dc.train_steps,
                       dtype=np.float64)


def alpha_bars(dc: DiffusionConfig) -> np.ndarray:
    return np.cumprod(1.0 - betas(dc))


def q_sample(x0: jnp.ndarray, t: jnp.ndarray, eps: jnp.ndarray,
             abar: jnp.ndarray) -> jnp.ndarray:
    """Forward diffusion x_t = √ᾱ_t x₀ + √(1-ᾱ_t) ε (eq. 1 iterated)."""
    a = abar[t].astype(jnp.float32)[:, None, None, None]
    return jnp.sqrt(a) * x0 + jnp.sqrt(1.0 - a) * eps


def loss_fn(params: Params, x0, t, y, eps, abar, cfg: ModelConfig):
    """DDPM noise-prediction MSE, eq. (11)."""
    xt = q_sample(x0, t, eps, abar)
    pred = forward(params, xt, t, y, cfg)
    return jnp.mean((pred - eps) ** 2)


# --------------------------------------------------------------------------
# Adam (no optax offline — hand-rolled, mirrored by the rust driver)
# --------------------------------------------------------------------------

def adam_init(params: Params) -> Adam:
    z = {k: jnp.zeros_like(v) for k, v in params.items()}
    return z, {k: jnp.zeros_like(v) for k, v in params.items()}


def train_step(params: Params, m: Params, v: Params, step: jnp.ndarray,
               x0, t, y, eps, abar, cfg: ModelConfig,
               lr: float = 2e-3, b1: float = 0.9, b2: float = 0.999,
               eps_adam: float = 1e-8):
    """One Adam step on the DDPM loss. Returns (params, m, v, loss)."""
    loss, grads = jax.value_and_grad(loss_fn)(params, x0, t, y, eps, abar,
                                              cfg)
    stepf = step.astype(jnp.float32) + 1.0
    new_p, new_m, new_v = {}, {}, {}
    for k in params:
        g = grads[k]
        new_m[k] = b1 * m[k] + (1.0 - b1) * g
        new_v[k] = b2 * v[k] + (1.0 - b2) * g * g
        mhat = new_m[k] / (1.0 - b1 ** stepf)
        vhat = new_v[k] / (1.0 - b2 ** stepf)
        new_p[k] = params[k] - lr * mhat / (jnp.sqrt(vhat) + eps_adam)
    return new_p, new_m, new_v, loss


def pretrain(cfg: ModelConfig, dc: DiffusionConfig, steps: int,
             batch: int, seed: int = 0, log_every: int = 200) -> Params:
    """Build-time pretraining of the scaled-down DiT on synthetic data."""
    rng = np.random.default_rng(seed)
    params = init_params(jax.random.PRNGKey(seed), cfg)
    m, v = adam_init(params)
    abar = jnp.asarray(alpha_bars(dc), jnp.float32)

    jit_step = jax.jit(
        lambda p, m_, v_, s, x0, t, y, e: train_step(
            p, m_, v_, s, x0, t, y, e, abar, cfg))

    for step in range(steps):
        x0, y = data_mod.sample_batch(rng, batch, cfg)
        t = rng.integers(0, dc.train_steps, size=(batch,))
        eps = rng.standard_normal(x0.shape).astype(np.float32)
        params, m, v, loss = jit_step(
            params, m, v, jnp.asarray(step, jnp.int32),
            jnp.asarray(x0), jnp.asarray(t, jnp.int32),
            jnp.asarray(y), jnp.asarray(eps))
        if step % log_every == 0 or step == steps - 1:
            print(f"[pretrain] step {step:5d} loss {float(loss):.4f}")
    return params


def flatten_params(params: Params, cfg: ModelConfig) -> List[jnp.ndarray]:
    return [params[k] for k in param_order(cfg)]


def unflatten_params(flat: List[jnp.ndarray], cfg: ModelConfig) -> Params:
    return dict(zip(param_order(cfg), flat))
