"""Model / diffusion / quantization-site configuration for TQ-DiT.

This module is the single source of truth for the scaled-down DiT used in
the reproduction (the paper uses DiT-XL-2 on ImageNet; see DESIGN.md §1
for the substitution rationale). Everything the Rust coordinator needs is
serialized into ``artifacts/manifest.json`` by ``aot.py``.
"""

from __future__ import annotations

import dataclasses
from typing import List


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Scaled-down DiT (same topology as DiT-XL-2, smaller dims)."""

    img_size: int = 16          # pixel-space "latent" resolution
    channels: int = 3
    patch: int = 2              # DiT-XL-*2* → patch size 2
    dim: int = 96               # hidden width
    depth: int = 3              # number of DiT blocks
    heads: int = 4
    num_classes: int = 8
    mlp_ratio: int = 4
    freq_dim: int = 96          # sinusoidal timestep-embedding width

    @property
    def tokens(self) -> int:
        side = self.img_size // self.patch
        return side * side

    @property
    def head_dim(self) -> int:
        assert self.dim % self.heads == 0
        return self.dim // self.heads

    @property
    def patch_dim(self) -> int:
        return self.patch * self.patch * self.channels

    @property
    def mlp_dim(self) -> int:
        return self.dim * self.mlp_ratio


@dataclasses.dataclass(frozen=True)
class DiffusionConfig:
    """DDPM with a linear beta schedule.

    The model is trained on ``t ∈ [0, T)`` with T = ``train_steps``; the
    paper's T=250 and T=100 samplers are obtained by running the full
    schedule (250) or a strided respacing (100) — see rust ``sched::ddpm``.
    """

    train_steps: int = 250
    beta_start: float = 1e-4
    beta_end: float = 0.02


@dataclasses.dataclass(frozen=True)
class QuantSite:
    """One activation quantization site.

    ``kind`` ∈ {"uniform", "mrq_softmax", "mrq_gelu"}. Every site owns a
    stride-4 slot in the flat ``qparams`` runtime input:

      uniform:     [s, z, n_levels, _]          (bypass when s <= 0)
      mrq_softmax: [s1, half_levels, _, _]      (s2 = 1/half_levels fixed)
      mrq_gelu:    [s1, s2, half_levels, _]     (R1 negative / R2 positive)
    """

    name: str
    kind: str
    tgq: bool = False           # per-time-group parameters (post-softmax)


@dataclasses.dataclass(frozen=True)
class Layer:
    """A quantizable compute layer (linear or matmul).

    Linear layers own one activation site (their input X) plus a
    host-side weight-quantization handle; MatMul layers own two
    activation sites (A and B).
    """

    name: str
    ltype: str                  # "linear" | "matmul"
    sites: List[QuantSite]
    weight: str = ""            # param name of the weight (linear only)


QP_STRIDE = 4


def build_layers(cfg: ModelConfig) -> List[Layer]:
    """Enumerate quantizable layers in execution order.

    Mirrors DESIGN.md §4. The post-GELU site is the X input of fc2; the
    post-softmax site is the A input of the AV MatMul (MRQ + TGQ).
    """
    layers: List[Layer] = [
        Layer("patch_embed", "linear",
              [QuantSite("patch_embed.x", "uniform")], "patch_embed.w"),
    ]
    for b in range(cfg.depth):
        p = f"blk{b}"
        layers += [
            Layer(f"{p}.adaln", "linear",
                  [QuantSite(f"{p}.adaln.x", "uniform")], f"{p}.adaln.w"),
            Layer(f"{p}.qkv", "linear",
                  [QuantSite(f"{p}.qkv.x", "uniform")], f"{p}.qkv.w"),
            Layer(f"{p}.qk", "matmul",
                  [QuantSite(f"{p}.qk.a", "uniform"),
                   QuantSite(f"{p}.qk.b", "uniform")]),
            Layer(f"{p}.av", "matmul",
                  [QuantSite(f"{p}.av.a", "mrq_softmax", tgq=True),
                   QuantSite(f"{p}.av.b", "uniform")]),
            Layer(f"{p}.proj", "linear",
                  [QuantSite(f"{p}.proj.x", "uniform")], f"{p}.proj.w"),
            Layer(f"{p}.fc1", "linear",
                  [QuantSite(f"{p}.fc1.x", "uniform")], f"{p}.fc1.w"),
            Layer(f"{p}.fc2", "linear",
                  [QuantSite(f"{p}.fc2.x", "mrq_gelu")], f"{p}.fc2.w"),
        ]
    layers.append(
        Layer("final", "linear",
              [QuantSite("final.x", "uniform")], "final.w"))
    return layers


def qparam_layout(cfg: ModelConfig):
    """Map each site name to its offset in the flat qparams vector."""
    offsets = {}
    off = 0
    for layer in build_layers(cfg):
        for site in layer.sites:
            offsets[site.name] = off
            off += QP_STRIDE
    return offsets, off


# Batch sizes baked into the AOT artifacts (fixed shapes).
CALIB_BATCH = 8        # dit_capture / dit_fp_calib
# Sampling-path batch ladder: the fp/quant sampling graphs are lowered
# once per rung so the serve layer can dispatch trickle traffic on
# small batches instead of padding the full one. Ascending; the largest
# rung keeps the classic unsuffixed artifact names, smaller rungs get
# `@b{B}` suffixes (see rust/src/runtime/artifacts.rs).
SAMPLE_LADDER = (1, 4, 16)
SAMPLE_BATCH = SAMPLE_LADDER[-1]   # dit_fp / dit_quant (sampling path)
TRAIN_BATCH = 64       # train_step

MODEL = ModelConfig()
DIFFUSION = DiffusionConfig()
