"""L2: full-precision DiT in JAX (differentiable; train + Fisher capture).

Same topology as DiT [Peebles & Xie 2023]: patchify → N adaLN-Zero
transformer blocks (MHSA + pointwise-FF with GELU) conditioned on
(timestep, class) → final adaLN linear → unpatchify, predicting the
noise ε. The quantized variant (``qmodel.py``) reuses the exact same
parameter tree and layer enumeration so quantization sites line up.

Parameters are a flat ``{name: array}`` dict; ``param_order`` fixes the
flattened ordering that the AOT artifacts and the rust ``weights.bin``
loader share.
"""

from __future__ import annotations

import math
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig

Params = Dict[str, jnp.ndarray]


# --------------------------------------------------------------------------
# parameter tree
# --------------------------------------------------------------------------

def param_specs(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    """(name, shape) list in the canonical flat order."""
    D, F, M = cfg.dim, cfg.freq_dim, cfg.mlp_dim
    specs: List[Tuple[str, Tuple[int, ...]]] = [
        ("patch_embed.w", (cfg.patch_dim, D)),
        ("patch_embed.b", (D,)),
        ("t_mlp.w1", (F, D)),
        ("t_mlp.b1", (D,)),
        ("t_mlp.w2", (D, D)),
        ("t_mlp.b2", (D,)),
        ("y_embed.w", (cfg.num_classes, D)),
        ("pos_embed", (cfg.tokens, D)),
    ]
    for b in range(cfg.depth):
        p = f"blk{b}"
        specs += [
            (f"{p}.adaln.w", (D, 6 * D)),
            (f"{p}.adaln.b", (6 * D,)),
            (f"{p}.qkv.w", (D, 3 * D)),
            (f"{p}.qkv.b", (3 * D,)),
            (f"{p}.proj.w", (D, D)),
            (f"{p}.proj.b", (D,)),
            (f"{p}.fc1.w", (D, M)),
            (f"{p}.fc1.b", (M,)),
            (f"{p}.fc2.w", (M, D)),
            (f"{p}.fc2.b", (D,)),
        ]
    specs += [
        ("final.adaln.w", (D, 2 * D)),
        ("final.adaln.b", (2 * D,)),
        ("final.w", (D, cfg.patch_dim)),
        ("final.b", (cfg.patch_dim,)),
    ]
    return specs


def param_order(cfg: ModelConfig) -> List[str]:
    return [n for n, _ in param_specs(cfg)]


def init_params(key: jax.Array, cfg: ModelConfig) -> Params:
    """Xavier-uniform linears; adaLN-Zero (modulation weights start at 0)."""
    params: Params = {}
    for name, shape in param_specs(cfg):
        key, sub = jax.random.split(key)
        if name.endswith(".b") or name.endswith("b1") or name.endswith("b2"):
            params[name] = jnp.zeros(shape, jnp.float32)
        elif "adaln.w" in name:
            # adaLN-Zero: zero-init modulation so each block starts as
            # identity (gates are 0) — matches the DiT paper.
            params[name] = jnp.zeros(shape, jnp.float32)
        elif name in ("pos_embed", "y_embed.w"):
            params[name] = 0.02 * jax.random.normal(sub, shape, jnp.float32)
        else:
            fan_in, fan_out = shape[0], shape[-1]
            lim = math.sqrt(6.0 / (fan_in + fan_out))
            params[name] = jax.random.uniform(
                sub, shape, jnp.float32, -lim, lim)
    return params


# --------------------------------------------------------------------------
# building blocks (pure jnp — differentiable)
# --------------------------------------------------------------------------

def timestep_embedding(t: jnp.ndarray, freq_dim: int,
                       max_period: float = 10_000.0) -> jnp.ndarray:
    """Sinusoidal timestep embedding (DDPM / DiT convention)."""
    half = freq_dim // 2
    freqs = jnp.exp(
        -math.log(max_period) * jnp.arange(half, dtype=jnp.float32) / half)
    args = t.astype(jnp.float32)[:, None] * freqs[None, :]
    return jnp.concatenate([jnp.cos(args), jnp.sin(args)], axis=-1)


def layer_norm(x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    """LayerNorm without learned affine (adaLN supplies modulation)."""
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps)


def gelu(x: jnp.ndarray) -> jnp.ndarray:
    """tanh-approximated GELU (matches the pallas kernel)."""
    c = math.sqrt(2.0 / math.pi)
    return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x ** 3)))


def silu(x: jnp.ndarray) -> jnp.ndarray:
    return x * jax.nn.sigmoid(x)


def patchify(x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """(B, H, W, C) → (B, N, patch_dim)."""
    B = x.shape[0]
    P, S = cfg.patch, cfg.img_size // cfg.patch
    x = x.reshape(B, S, P, S, P, cfg.channels)
    x = x.transpose(0, 1, 3, 2, 4, 5)
    return x.reshape(B, S * S, cfg.patch_dim)


def unpatchify(tok: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """(B, N, patch_dim) → (B, H, W, C)."""
    B = tok.shape[0]
    P, S = cfg.patch, cfg.img_size // cfg.patch
    x = tok.reshape(B, S, S, P, P, cfg.channels)
    x = x.transpose(0, 1, 3, 2, 4, 5)
    return x.reshape(B, cfg.img_size, cfg.img_size, cfg.channels)


# --------------------------------------------------------------------------
# forward pass with optional capture / delta injection (for Fisher)
# --------------------------------------------------------------------------

def forward_aux(params: Params, x: jnp.ndarray, t: jnp.ndarray,
                y: jnp.ndarray, cfg: ModelConfig,
                deltas: Optional[Params] = None,
                collect: bool = False):
    """FP forward.

    ``deltas`` — optional {layer_name: tensor} added to each quantizable
    layer's pre-activation output z; ``jax.grad`` w.r.t. these at zero
    yields ∂L/∂z, the diagonal-Fisher ingredient of eq. (15)/(16).

    ``collect=True`` additionally returns each quantizable layer's
    inputs (X for linears; A, B for matmuls) so the rust coordinator can
    evaluate the HO objective host-side.

    Returns (eps_pred, aux) where aux = {"in": {site_name: tensor}}.
    """
    B = x.shape[0]
    D, H = cfg.dim, cfg.heads
    hd, N = cfg.head_dim, cfg.tokens
    aux_in: Dict[str, jnp.ndarray] = {}

    def dz(name: str, z: jnp.ndarray) -> jnp.ndarray:
        if deltas is not None and name in deltas:
            z = z + deltas[name]
        return z

    def cap(name: str, v: jnp.ndarray) -> None:
        if collect:
            aux_in[name] = v

    # --- embeddings -------------------------------------------------------
    ptok = patchify(x, cfg)
    cap("patch_embed.x", ptok)
    tok = dz("patch_embed",
             ptok @ params["patch_embed.w"] + params["patch_embed.b"])
    tok = tok + params["pos_embed"][None]

    temb = timestep_embedding(t, cfg.freq_dim)
    c = silu(temb @ params["t_mlp.w1"] + params["t_mlp.b1"])
    c = c @ params["t_mlp.w2"] + params["t_mlp.b2"]
    c = c + params["y_embed.w"][y]

    # --- DiT blocks -------------------------------------------------------
    for b in range(cfg.depth):
        p = f"blk{b}"
        cvec = silu(c)
        cap(f"{p}.adaln.x", cvec)
        mod = dz(f"{p}.adaln",
                 cvec @ params[f"{p}.adaln.w"] + params[f"{p}.adaln.b"])
        sh1, sc1, g1, sh2, sc2, g2 = jnp.split(mod, 6, axis=-1)

        # MHSA
        h = layer_norm(tok) * (1.0 + sc1[:, None, :]) + sh1[:, None, :]
        cap(f"{p}.qkv.x", h)
        qkv = dz(f"{p}.qkv", h @ params[f"{p}.qkv.w"] + params[f"{p}.qkv.b"])
        qkv = qkv.reshape(B, N, 3, H, hd).transpose(2, 0, 3, 1, 4)
        q, k, v = qkv[0], qkv[1], qkv[2]          # (B, H, N, hd)
        cap(f"{p}.qk.a", q)
        cap(f"{p}.qk.b", k)
        att = dz(f"{p}.qk", jnp.einsum("bhnd,bhmd->bhnm", q, k))
        att = att / math.sqrt(hd)
        sm = jax.nn.softmax(att, axis=-1)
        cap(f"{p}.av.a", sm)
        cap(f"{p}.av.b", v)
        o = dz(f"{p}.av", jnp.einsum("bhnm,bhmd->bhnd", sm, v))
        o = o.transpose(0, 2, 1, 3).reshape(B, N, D)
        cap(f"{p}.proj.x", o)
        o = dz(f"{p}.proj", o @ params[f"{p}.proj.w"] + params[f"{p}.proj.b"])
        tok = tok + g1[:, None, :] * o

        # pointwise feed-forward
        h2 = layer_norm(tok) * (1.0 + sc2[:, None, :]) + sh2[:, None, :]
        cap(f"{p}.fc1.x", h2)
        u = dz(f"{p}.fc1", h2 @ params[f"{p}.fc1.w"] + params[f"{p}.fc1.b"])
        g = gelu(u)
        cap(f"{p}.fc2.x", g)
        m = dz(f"{p}.fc2", g @ params[f"{p}.fc2.w"] + params[f"{p}.fc2.b"])
        tok = tok + g2[:, None, :] * m

    # --- final layer ------------------------------------------------------
    fmod = silu(c) @ params["final.adaln.w"] + params["final.adaln.b"]
    fsh, fsc = jnp.split(fmod, 2, axis=-1)
    h = layer_norm(tok) * (1.0 + fsc[:, None, :]) + fsh[:, None, :]
    cap("final.x", h)
    out = dz("final", h @ params["final.w"] + params["final.b"])
    eps = unpatchify(out, cfg)
    return eps, {"in": aux_in}


def forward(params: Params, x: jnp.ndarray, t: jnp.ndarray,
            y: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """Plain FP forward: predicted noise ε_θ(x_t, t, y)."""
    eps, _ = forward_aux(params, x, t, y, cfg)
    return eps


def layer_z_shapes(cfg: ModelConfig, batch: int) -> Dict[str, Tuple[int, ...]]:
    """Pre-activation output shapes per quantizable layer (for deltas)."""
    D, H, M = cfg.dim, cfg.heads, cfg.mlp_dim
    N, hd = cfg.tokens, cfg.head_dim
    shapes: Dict[str, Tuple[int, ...]] = {
        "patch_embed": (batch, N, D),
        "final": (batch, N, cfg.patch_dim),
    }
    for b in range(cfg.depth):
        p = f"blk{b}"
        shapes[f"{p}.adaln"] = (batch, 6 * D)
        shapes[f"{p}.qkv"] = (batch, N, 3 * D)
        shapes[f"{p}.qk"] = (batch, H, N, N)
        shapes[f"{p}.av"] = (batch, H, N, hd)
        shapes[f"{p}.proj"] = (batch, N, D)
        shapes[f"{p}.fc1"] = (batch, N, M)
        shapes[f"{p}.fc2"] = (batch, N, D)
    return shapes
