"""Metric networks: FID/sFID feature extractor + IS classifier.

InceptionV3 substitute (DESIGN.md §1):

* ``feature_net`` — a small *fixed random* CNN (random-feature FID is a
  standard proxy). Returns (feat, spat): pooled features (B, 64) for FID
  and a flattened mid-layer spatial map (B, 192) for sFID.
* ``classifier``  — a small CNN *trained* on the synthetic classes at
  artifact-build time; its softmax drives the Inception Score.

Both are exported with weights baked in as constants, so the rust side
only feeds images.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import data as data_mod
from .config import ModelConfig

FEAT_DIM = 64
SPAT_DIM = 192     # 4 x 4 x 12
NUM_FEAT_BATCH = 64

# Canonical parameter orders shared with the rust metric-weights loader
# (aot.py writes metric_weights.bin in this order, f32 LE).
FEAT_PARAM_ORDER = ["c1", "c2", "c3"]
CLF_PARAM_ORDER = ["c1", "c2", "d", "b"]


def _conv(x, w, stride=1):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def _avgpool2(x):
    return jax.lax.reduce_window(
        x, 0.0, jax.lax.add, (1, 2, 2, 1), (1, 2, 2, 1), "VALID") / 4.0


# --------------------------------------------------------------------------
# FID / sFID feature net (fixed random weights)
# --------------------------------------------------------------------------

def feature_params(seed: int = 7) -> Dict[str, jnp.ndarray]:
    rng = np.random.default_rng(seed)

    def w(shape, fan_in):
        return jnp.asarray(
            rng.standard_normal(shape) / np.sqrt(fan_in), jnp.float32)

    return {
        "c1": w((3, 3, 3, 16), 27),
        "c2": w((3, 3, 16, 12), 144),
        "c3": w((3, 3, 12, 64), 108),
    }


def feature_net(fp: Dict[str, jnp.ndarray],
                img: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """img (B,16,16,3) in [-1,1] → (feat (B,64), spat (B,192))."""
    h = jax.nn.relu(_conv(img, fp["c1"]))      # (B,16,16,16)
    h = _avgpool2(h)                           # (B, 8, 8,16)
    s = jax.nn.relu(_conv(h, fp["c2"]))        # (B, 8, 8,12)
    sp = _avgpool2(s)                          # (B, 4, 4,12)
    spat = sp.reshape(sp.shape[0], SPAT_DIM)
    f = jax.nn.relu(_conv(s, fp["c3"]))        # (B, 8, 8,64)
    feat = jnp.mean(f, axis=(1, 2))            # (B,64)
    return feat, spat


# --------------------------------------------------------------------------
# IS classifier (trained briefly on the synthetic classes)
# --------------------------------------------------------------------------

def classifier_init(cfg: ModelConfig, seed: int = 11):
    rng = np.random.default_rng(seed)

    def w(shape, fan_in):
        return jnp.asarray(
            rng.standard_normal(shape) / np.sqrt(fan_in), jnp.float32)

    return {
        "c1": w((3, 3, 3, 16), 27),
        "c2": w((3, 3, 16, 32), 144),
        "d": w((4 * 4 * 32, cfg.num_classes), 4 * 4 * 32),
        "b": jnp.zeros((cfg.num_classes,), jnp.float32),
    }


def classifier_logits(cp, img: jnp.ndarray) -> jnp.ndarray:
    h = jax.nn.relu(_conv(img, cp["c1"], stride=2))   # (B,8,8,16)
    h = jax.nn.relu(_conv(h, cp["c2"], stride=2))     # (B,4,4,32)
    h = h.reshape(h.shape[0], -1)
    return h @ cp["d"] + cp["b"]


def train_classifier(cfg: ModelConfig, steps: int = 400, batch: int = 128,
                     lr: float = 1e-3, seed: int = 13):
    """Quick Adam training; returns params and final accuracy."""
    rng = np.random.default_rng(seed)
    cp = classifier_init(cfg)
    m = {k: jnp.zeros_like(v) for k, v in cp.items()}
    v = {k: jnp.zeros_like(val) for k, val in cp.items()}

    def loss_fn(cp, img, y):
        logits = classifier_logits(cp, img)
        logp = jax.nn.log_softmax(logits)
        return -jnp.mean(logp[jnp.arange(y.shape[0]), y])

    @jax.jit
    def step_fn(cp, m, v, step, img, y):
        loss, g = jax.value_and_grad(loss_fn)(cp, img, y)
        sf = step.astype(jnp.float32) + 1.0
        out_p, out_m, out_v = {}, {}, {}
        for k in cp:
            out_m[k] = 0.9 * m[k] + 0.1 * g[k]
            out_v[k] = 0.999 * v[k] + 0.001 * g[k] * g[k]
            mh = out_m[k] / (1 - 0.9 ** sf)
            vh = out_v[k] / (1 - 0.999 ** sf)
            out_p[k] = cp[k] - lr * mh / (jnp.sqrt(vh) + 1e-8)
        return out_p, out_m, out_v, loss

    for s in range(steps):
        img, y = data_mod.sample_batch(rng, batch, cfg)
        cp, m, v, loss = step_fn(cp, m, v, jnp.asarray(s, jnp.int32),
                                 jnp.asarray(img), jnp.asarray(y))
    img, y = data_mod.sample_batch(rng, 512, cfg)
    acc = float(jnp.mean(
        jnp.argmax(classifier_logits(cp, jnp.asarray(img)), -1) == y))
    print(f"[classifier] final loss {float(loss):.4f} acc {acc:.3f}")
    return cp, acc


# --------------------------------------------------------------------------
# reference FID statistics over the synthetic data distribution
# --------------------------------------------------------------------------

def reference_stats(cfg: ModelConfig, n: int = 4096, seed: int = 17):
    """(mu_f, cov_f, mu_s, cov_s) over `n` real synthetic images."""
    rng = np.random.default_rng(seed)
    fp = feature_params()
    fnet = jax.jit(lambda im: feature_net(fp, im))
    feats, spats = [], []
    bs = 256
    for _ in range(n // bs):
        img, _ = data_mod.sample_batch(rng, bs, cfg)
        f, s = fnet(jnp.asarray(img))
        feats.append(np.asarray(f))
        spats.append(np.asarray(s))
    F = np.concatenate(feats)
    S = np.concatenate(spats)
    return (F.mean(0), np.cov(F, rowvar=False),
            S.mean(0), np.cov(S, rowvar=False))
