"""Pallas kernels (L1) + pure-jnp oracles.

All kernels run with interpret=True (CPU PJRT cannot execute Mosaic
custom-calls); see DESIGN.md §2 for the TPU mapping.
"""
from .quant import fakequant_uniform
from .mrq import mrq_softmax, mrq_gelu
from .qmatmul import qmatmul

__all__ = ["fakequant_uniform", "mrq_softmax", "mrq_gelu", "qmatmul"]
