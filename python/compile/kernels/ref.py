"""Pure-jnp oracles for the pallas kernels — the correctness ground truth.

Every pallas kernel in this package has an exact jnp twin here; pytest
(``python/tests/test_kernels.py``) sweeps shapes/params with hypothesis
and asserts allclose. The quantized model also has a kernel-free
reference path (``qmodel.forward_quant_ref``) built from these.

Quantization-parameter encoding (stride-4 slots, see config.QuantSite):
  uniform:     qp = [s, z, n_levels, _]        bypass when s <= 0
  mrq_softmax: qp = [s1, half_levels, _, _]    s2 = 1/half_levels fixed
  mrq_gelu:    qp = [s1, s2, half_levels, _]   R1 negative / R2 positive
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def fakequant_uniform_ref(x: jnp.ndarray, qp: jnp.ndarray) -> jnp.ndarray:
    """Uniform asymmetric fake-quant, eq. (5) of the paper."""
    s, z, levels = qp[0], qp[1], qp[2]
    q = jnp.clip(jnp.round(x / jnp.where(s > 0, s, 1.0)) + z, 0.0, levels)
    y = (q - z) * s
    return jnp.where(s > 0, y, x)


def mrq_softmax_ref(logits: jnp.ndarray, qp: jnp.ndarray) -> jnp.ndarray:
    """Softmax over the last axis fused with multi-region fake-quant.

    Region split (paper §III-C): R1 = [0, 2^{k-1}·s1) with step s1,
    R2 = [2^{k-1}·s1, 1] with fixed step s2 = 1/2^{k-1}.
    """
    p = jax.nn.softmax(logits, axis=-1)
    s1, half = qp[0], qp[1]
    s2 = 1.0 / jnp.where(half > 0, half, 1.0)
    boundary = half * s1
    q1 = jnp.clip(jnp.round(p / jnp.where(s1 > 0, s1, 1.0)), 0.0,
                  half - 1.0) * s1
    q2 = jnp.clip(jnp.round(p / s2), 0.0, half) * s2
    y = jnp.where(p < boundary, q1, q2)
    return jnp.where(s1 > 0, y, p)


def gelu_ref(x: jnp.ndarray) -> jnp.ndarray:
    c = math.sqrt(2.0 / math.pi)
    return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x ** 3)))


def mrq_gelu_ref(x: jnp.ndarray, qp: jnp.ndarray) -> jnp.ndarray:
    """tanh-GELU fused with two-region fake-quant.

    R1 = [-2^{k-1}·s1, 0] (negative tail, step s1);
    R2 = [0, 2^{k-1}·s2)  (positive side, step s2).
    """
    g = gelu_ref(x)
    s1, s2, half = qp[0], qp[1], qp[2]
    q1 = jnp.clip(jnp.round(g / jnp.where(s1 > 0, s1, 1.0)),
                  -half, 0.0) * s1
    q2 = jnp.clip(jnp.round(g / jnp.where(s2 > 0, s2, 1.0)),
                  0.0, half - 1.0) * s2
    y = jnp.where(g < 0, q1, q2)
    return jnp.where(s1 > 0, y, g)


def qmatmul_ref(a: jnp.ndarray, b: jnp.ndarray, qpa: jnp.ndarray,
                qpb: jnp.ndarray) -> jnp.ndarray:
    """Batched fake-quantized matmul: fq(a) @ fq(b), (G,M,K)x(G,K,N)."""
    aq = fakequant_uniform_ref(a, qpa)
    bq = fakequant_uniform_ref(b, qpb)
    return jnp.einsum("gmk,gkn->gmn", aq, bq)
