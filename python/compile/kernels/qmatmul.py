"""L1 pallas kernel: batched fake-quantized matmul (the MatMul hot-spot).

Computes ``fq(A) @ fq(B)`` for A: (G, M, K), B: (G, K, N) where G is a
flattened batch×heads dimension. TPU mapping (DESIGN.md §2): grid over
(G, M-tiles); each step fake-quantizes its A tile and the full-K B panel
in VMEM (VPU elementwise) and runs the f32 ``jnp.dot`` accumulation that
maps onto the MXU systolic array. For DiT attention shapes (K = head_dim
or tokens, both small) the K axis stays resident, so there is no
K-loop carry; the M-tile size bounds VMEM use.

Uniform-slot encoding as in ``quant.py``; ``s <= 0`` bypasses the quant
(used for the AV matmul whose A input was already MRQ-quantized inside
the fused softmax kernel).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .quant import _pick_rows


def _fq(x, s, z, levels):
    safe = jnp.where(s > 0, s, 1.0)
    q = jnp.clip(jnp.round(x / safe) + z, 0.0, levels)
    return jnp.where(s > 0, (q - z) * s, x)


def _qmm_kernel(a_ref, b_ref, qpa_ref, qpb_ref, o_ref):
    a = a_ref[0]                       # (bm, K)
    b = b_ref[0]                       # (K, N)
    aq = _fq(a, qpa_ref[0, 0], qpa_ref[0, 1], qpa_ref[0, 2])
    bq = _fq(b, qpb_ref[0, 0], qpb_ref[0, 1], qpb_ref[0, 2])
    o_ref[0] = jnp.dot(aq, bq, preferred_element_type=jnp.float32)


def qmatmul(a: jnp.ndarray, b: jnp.ndarray, qpa: jnp.ndarray,
            qpb: jnp.ndarray) -> jnp.ndarray:
    """Batched quantized matmul: (G, M, K) x (G, K, N) → (G, M, N)."""
    G, M, K = a.shape
    _, _, N = b.shape
    bm = _pick_rows(M)
    out = pl.pallas_call(
        _qmm_kernel,
        out_shape=jax.ShapeDtypeStruct((G, M, N), jnp.float32),
        grid=(G, M // bm),
        in_specs=[
            pl.BlockSpec((1, bm, K), lambda g, i: (g, i, 0)),
            pl.BlockSpec((1, K, N), lambda g, i: (g, 0, 0)),
            pl.BlockSpec((1, 4), lambda g, i: (0, 0)),
            pl.BlockSpec((1, 4), lambda g, i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bm, N), lambda g, i: (g, i, 0)),
        interpret=True,
    )(a, b, qpa.reshape(1, 4), qpb.reshape(1, 4))
    return out
