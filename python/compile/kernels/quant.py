"""L1 pallas kernel: uniform asymmetric fake-quantization (eq. 5).

TPU mapping (DESIGN.md §2): the tensor is flattened to (rows, cols) and
row-tiled so each block fits VMEM; the quant math is elementwise VPU
work. The 4-float parameter slot rides along as a (1, 4) block that every
grid step maps to the same origin (the TPU analogue of a scalar SMEM
operand).

``interpret=True`` everywhere — the CPU PJRT client cannot execute
Mosaic custom-calls; structure (BlockSpec schedule) is still the real
thing and is what the §Perf VMEM/MXU estimates are computed from.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Row-block target: 8 KiB-ish blocks keep dozens of live blocks well under
# a 16 MiB VMEM budget even with double buffering.
_BLOCK_ROWS = 256


def _fq_kernel(x_ref, qp_ref, o_ref):
    x = x_ref[...]
    s, z, levels = qp_ref[0, 0], qp_ref[0, 1], qp_ref[0, 2]
    safe = jnp.where(s > 0, s, 1.0)
    q = jnp.clip(jnp.round(x / safe) + z, 0.0, levels)
    o_ref[...] = jnp.where(s > 0, (q - z) * s, x)


def _pick_rows(rows: int) -> int:
    """Largest divisor of ``rows`` not exceeding the block target."""
    best = 1
    d = 1
    while d * d <= rows:
        if rows % d == 0:
            for c in (d, rows // d):
                if c <= _BLOCK_ROWS and c > best:
                    best = c
        d += 1
    return best


def fakequant_uniform(x: jnp.ndarray, qp: jnp.ndarray) -> jnp.ndarray:
    """Fake-quantize any-shape tensor with a stride-4 uniform slot."""
    shape = x.shape
    cols = shape[-1]
    rows = 1
    for s in shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, cols)
    br = _pick_rows(rows)
    qp2 = qp.reshape(1, 4)
    out = pl.pallas_call(
        _fq_kernel,
        out_shape=jax.ShapeDtypeStruct((rows, cols), x.dtype),
        grid=(rows // br,),
        in_specs=[
            pl.BlockSpec((br, cols), lambda i: (i, 0)),
            pl.BlockSpec((1, 4), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((br, cols), lambda i: (i, 0)),
        interpret=True,
    )(x2, qp2)
    return out.reshape(shape)
