"""L1 pallas kernels: multi-region quantization (paper §III-C), fused.

Two kernels:

* ``mrq_softmax`` — row softmax fused with the two-region post-softmax
  fake-quant. The paper quantizes *after* softmax; fusing the quant as a
  softmax epilogue saves one HBM round-trip of the (rows × N) attention
  matrix — the TPU rethink of the paper's GPU post-hoc quant pass.
  R1 = [0, 2^{k-1}·s1) step s1 (calibrated), R2 = [2^{k-1}·s1, 1] step
  s2 = 1/2^{k-1} (fixed), exactly the twin-uniform design the paper
  adapts from PTQ4ViT.

* ``mrq_gelu`` — tanh-GELU fused with the two-region (negative/positive)
  fake-quant: R1 = [-2^{k-1}·s1, 0] step s1, R2 = [0, 2^{k-1}·s2) step s2.

Both are row-tiled over VMEM-sized blocks; softmax keeps the full
reduction axis inside one block (N = tokens is small for DiT patches).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .quant import _pick_rows


def _mrq_softmax_kernel(x_ref, qp_ref, o_ref):
    x = x_ref[...]
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    p = e / jnp.sum(e, axis=-1, keepdims=True)

    s1, half = qp_ref[0, 0], qp_ref[0, 1]
    safe1 = jnp.where(s1 > 0, s1, 1.0)
    s2 = 1.0 / jnp.where(half > 0, half, 1.0)
    boundary = half * s1
    q1 = jnp.clip(jnp.round(p / safe1), 0.0, half - 1.0) * s1
    q2 = jnp.clip(jnp.round(p / s2), 0.0, half) * s2
    y = jnp.where(p < boundary, q1, q2)
    o_ref[...] = jnp.where(s1 > 0, y, p)


def _mrq_gelu_kernel(x_ref, qp_ref, o_ref):
    x = x_ref[...]
    c = 0.7978845608028654  # sqrt(2/pi)
    g = 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x ** 3)))

    s1, s2, half = qp_ref[0, 0], qp_ref[0, 1], qp_ref[0, 2]
    safe1 = jnp.where(s1 > 0, s1, 1.0)
    safe2 = jnp.where(s2 > 0, s2, 1.0)
    q1 = jnp.clip(jnp.round(g / safe1), -half, 0.0) * s1
    q2 = jnp.clip(jnp.round(g / safe2), 0.0, half - 1.0) * s2
    y = jnp.where(g < 0, q1, q2)
    o_ref[...] = jnp.where(s1 > 0, y, g)


def _rowwise(kernel, x: jnp.ndarray, qp: jnp.ndarray) -> jnp.ndarray:
    shape = x.shape
    cols = shape[-1]
    rows = 1
    for s in shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, cols)
    br = _pick_rows(rows)
    out = pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((rows, cols), x.dtype),
        grid=(rows // br,),
        in_specs=[
            pl.BlockSpec((br, cols), lambda i: (i, 0)),
            pl.BlockSpec((1, 4), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((br, cols), lambda i: (i, 0)),
        interpret=True,
    )(x2, qp.reshape(1, 4))
    return out.reshape(shape)


def mrq_softmax(logits: jnp.ndarray, qp: jnp.ndarray) -> jnp.ndarray:
    """Softmax over the last axis + multi-region fake-quant (fused)."""
    return _rowwise(_mrq_softmax_kernel, logits, qp)


def mrq_gelu(x: jnp.ndarray, qp: jnp.ndarray) -> jnp.ndarray:
    """tanh-GELU + two-region fake-quant (fused)."""
    return _rowwise(_mrq_gelu_kernel, x, qp)
