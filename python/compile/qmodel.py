"""L2: quantized DiT forward — quantization parameters are RUNTIME inputs.

The quantized forward mirrors ``model.forward`` exactly, but every
quantization site (config.build_layers) applies fake-quant driven by a
flat f32 ``qparams`` vector whose layout is ``config.qparam_layout``.
``s <= 0`` in a slot bypasses that site (full precision), so a single
AOT-compiled executable serves FP, any uniform/MRQ configuration, every
bit-width, and every TGQ time-group — the rust coordinator just swaps
the vector between calls. Weights arrive already fake-quantized (weight
quantization is host-side in rust; see DESIGN.md §3).

Two interchangeable op sets:
  * ``PALLAS_OPS`` — the pallas kernels (what the shipped artifact uses)
  * ``REF_OPS``    — pure-jnp oracles (pytest equivalence target)
"""

from __future__ import annotations

import math
from typing import Callable, Dict, NamedTuple

import jax.numpy as jnp

from .config import ModelConfig, QP_STRIDE, qparam_layout
from .kernels import fakequant_uniform, mrq_gelu, mrq_softmax, qmatmul
from .kernels import ref as kref
from .model import (Params, layer_norm, patchify, silu, timestep_embedding,
                    unpatchify)


class QuantOps(NamedTuple):
    fakequant: Callable
    mrq_softmax: Callable
    mrq_gelu: Callable
    qmatmul: Callable


PALLAS_OPS = QuantOps(fakequant_uniform, mrq_softmax, mrq_gelu, qmatmul)
REF_OPS = QuantOps(kref.fakequant_uniform_ref, kref.mrq_softmax_ref,
                   kref.mrq_gelu_ref, kref.qmatmul_ref)


def forward_quant(params: Params, x: jnp.ndarray, t: jnp.ndarray,
                  y: jnp.ndarray, qparams: jnp.ndarray, cfg: ModelConfig,
                  ops: QuantOps = PALLAS_OPS) -> jnp.ndarray:
    """Quantized ε_θ(x_t, t, y; Δ). ``qparams``: (qp_len,) f32."""
    B = x.shape[0]
    D, H = cfg.dim, cfg.heads
    hd, N = cfg.head_dim, cfg.tokens
    offsets, _ = qparam_layout(cfg)

    def qp(site: str) -> jnp.ndarray:
        off = offsets[site]
        return jnp.asarray(qparams[off:off + QP_STRIDE])

    bypass = jnp.zeros((QP_STRIDE,), jnp.float32)

    # --- embeddings (t/y-embedding MLPs stay FP — see DESIGN.md §4) ------
    ptok = ops.fakequant(patchify(x, cfg), qp("patch_embed.x"))
    tok = ptok @ params["patch_embed.w"] + params["patch_embed.b"]
    tok = tok + params["pos_embed"][None]

    temb = timestep_embedding(t, cfg.freq_dim)
    c = silu(temb @ params["t_mlp.w1"] + params["t_mlp.b1"])
    c = c @ params["t_mlp.w2"] + params["t_mlp.b2"]
    c = c + params["y_embed.w"][y]

    # --- DiT blocks -------------------------------------------------------
    for b in range(cfg.depth):
        p = f"blk{b}"
        cvec = ops.fakequant(silu(c), qp(f"{p}.adaln.x"))
        mod = cvec @ params[f"{p}.adaln.w"] + params[f"{p}.adaln.b"]
        sh1, sc1, g1, sh2, sc2, g2 = jnp.split(mod, 6, axis=-1)

        # MHSA: QK^T and AV are MatMul layers (paper Alg. 1 line 23);
        # post-softmax is the MRQ+TGQ site, fused into the softmax kernel.
        h = layer_norm(tok) * (1.0 + sc1[:, None, :]) + sh1[:, None, :]
        hq = ops.fakequant(h, qp(f"{p}.qkv.x"))
        qkv = hq @ params[f"{p}.qkv.w"] + params[f"{p}.qkv.b"]
        qkv = qkv.reshape(B, N, 3, H, hd).transpose(2, 0, 3, 1, 4)
        q, k, v = qkv[0], qkv[1], qkv[2]                  # (B, H, N, hd)

        att = ops.qmatmul(q.reshape(B * H, N, hd),
                          k.transpose(0, 1, 3, 2).reshape(B * H, hd, N),
                          qp(f"{p}.qk.a"), qp(f"{p}.qk.b"))
        att = att / math.sqrt(hd)
        sm = ops.mrq_softmax(att, qp(f"{p}.av.a"))        # fused MRQ+TGQ
        o = ops.qmatmul(sm, v.reshape(B * H, N, hd),
                        bypass, qp(f"{p}.av.b"))
        o = o.reshape(B, H, N, hd).transpose(0, 2, 1, 3).reshape(B, N, D)
        oq = ops.fakequant(o, qp(f"{p}.proj.x"))
        o = oq @ params[f"{p}.proj.w"] + params[f"{p}.proj.b"]
        tok = tok + g1[:, None, :] * o

        # pointwise feed-forward; fc2's input site IS the post-GELU MRQ.
        h2 = layer_norm(tok) * (1.0 + sc2[:, None, :]) + sh2[:, None, :]
        h2q = ops.fakequant(h2, qp(f"{p}.fc1.x"))
        u = h2q @ params[f"{p}.fc1.w"] + params[f"{p}.fc1.b"]
        g = ops.mrq_gelu(u, qp(f"{p}.fc2.x"))             # fused GELU+MRQ
        m = g @ params[f"{p}.fc2.w"] + params[f"{p}.fc2.b"]
        tok = tok + g2[:, None, :] * m

    # --- final layer ------------------------------------------------------
    fmod = silu(c) @ params["final.adaln.w"] + params["final.adaln.b"]
    fsh, fsc = jnp.split(fmod, 2, axis=-1)
    h = layer_norm(tok) * (1.0 + fsc[:, None, :]) + fsh[:, None, :]
    hq = ops.fakequant(h, qp("final.x"))
    out = hq @ params["final.w"] + params["final.b"]
    return unpatchify(out, cfg)


def forward_quant_ref(params: Params, x, t, y, qparams, cfg: ModelConfig):
    """Kernel-free reference path (oracles only) for pytest equivalence."""
    return forward_quant(params, x, t, y, qparams, cfg, ops=REF_OPS)
