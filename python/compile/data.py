"""Synthetic class-conditional dataset (the ImageNet substitute).

8 procedural pattern classes over 16x16x3 images in [-1, 1]. The same
generator is implemented in rust (``data::synth``) with identical class
parameterization so calibration tuples built on the rust side come from
the same distribution the model was trained on (DESIGN.md §1).

Class parameterization (k = 0..C-1):
  * even k  → gaussian blob at a class-dependent position, class hue
  * odd  k  → sinusoidal stripes with class-dependent frequency/angle
Both get a small amount of additive noise.
"""

from __future__ import annotations

import numpy as np

from .config import ModelConfig

# Deterministic per-class geometry/hue tables (shared with rust).
_PHI = 0.61803398875


def class_params(k: int, num_classes: int):
    """Deterministic class geometry — mirrored in rust data/synth.rs."""
    u = (k * _PHI) % 1.0
    cx = 0.25 + 0.5 * u
    cy = 0.25 + 0.5 * ((u + 0.37) % 1.0)
    sigma = 0.12 + 0.10 * ((k * 2654435761) % 97) / 97.0
    hue = np.array([
        0.5 + 0.5 * np.cos(2 * np.pi * (u + 0.00)),
        0.5 + 0.5 * np.cos(2 * np.pi * (u + 1 / 3)),
        0.5 + 0.5 * np.cos(2 * np.pi * (u + 2 / 3)),
    ])
    freq = 1.0 + (k % 4)
    angle = np.pi * u
    return cx, cy, sigma, hue, freq, angle


def make_batch(rng: np.random.Generator, labels: np.ndarray,
               cfg: ModelConfig) -> np.ndarray:
    """Generate a batch of images (B, H, W, C) in [-1, 1] for labels."""
    B = labels.shape[0]
    H = W = cfg.img_size
    ys, xs = np.meshgrid(
        np.linspace(0.0, 1.0, H), np.linspace(0.0, 1.0, W), indexing="ij")
    out = np.zeros((B, H, W, cfg.channels), dtype=np.float32)
    for i in range(B):
        k = int(labels[i])
        cx, cy, sigma, hue, freq, angle = class_params(k, cfg.num_classes)
        if k % 2 == 0:
            d2 = (xs - cx) ** 2 + (ys - cy) ** 2
            base = np.exp(-d2 / (2.0 * sigma * sigma))
        else:
            proj = np.cos(angle) * xs + np.sin(angle) * ys
            base = 0.5 + 0.5 * np.sin(2.0 * np.pi * freq * proj)
        img = base[..., None] * hue[None, None, :]
        img = 2.0 * img - 1.0
        img += 0.05 * rng.standard_normal(img.shape)
        out[i] = np.clip(img, -1.0, 1.0)
    return out.astype(np.float32)


def sample_batch(rng: np.random.Generator, batch: int, cfg: ModelConfig):
    """Random labels + images."""
    labels = rng.integers(0, cfg.num_classes, size=(batch,))
    return make_batch(rng, labels, cfg), labels.astype(np.int32)
