"""L2 model tests: parameter tree, shapes, adaLN-Zero init behaviour,
capture/delta plumbing used by the Fisher artifact."""

import jax
import jax.numpy as jnp
import numpy as np

from compile.config import MODEL, build_layers, qparam_layout, QP_STRIDE
from compile.model import (forward, forward_aux, init_params,
                           layer_z_shapes, param_specs, patchify,
                           timestep_embedding, unpatchify)

jax.config.update("jax_platform_name", "cpu")

CFG = MODEL


def tiny_inputs(b=2, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(
        (b, CFG.img_size, CFG.img_size, CFG.channels)), jnp.float32)
    t = jnp.asarray(rng.integers(0, 250, size=(b,)), jnp.int32)
    y = jnp.asarray(rng.integers(0, CFG.num_classes, size=(b,)), jnp.int32)
    return x, t, y


def test_param_specs_unique_and_shaped():
    specs = param_specs(CFG)
    names = [n for n, _ in specs]
    assert len(names) == len(set(names))
    total = sum(int(np.prod(s)) for _, s in specs)
    assert total > 10_000  # non-trivial model
    # canonical first/last entries the rust loader assumes
    assert names[0] == "patch_embed.w"
    assert names[-1] == "final.b"


def test_forward_shape_and_finite():
    params = init_params(jax.random.PRNGKey(0), CFG)
    x, t, y = tiny_inputs()
    eps = forward(params, x, t, y, CFG)
    assert eps.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(eps)))


def test_adaln_zero_init_blocks_are_identity():
    """With zero-init adaLN, block gates are 0 → tokens pass through, so
    two different x produce outputs whose difference is linear in the
    final layer only (gates make the blocks' contribution vanish)."""
    params = init_params(jax.random.PRNGKey(1), CFG)
    x, t, y = tiny_inputs()
    eps1, aux = forward_aux(params, x, t, y, CFG, collect=True)
    # gate g1 comes from adaln output == bias == 0 at init
    for b in range(CFG.depth):
        mod = np.asarray(aux["in"][f"blk{b}.qkv.x"])
        assert np.all(np.isfinite(mod))
    assert eps1.shape == x.shape


def test_patchify_unpatchify_roundtrip():
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal(
        (2, CFG.img_size, CFG.img_size, CFG.channels)), jnp.float32)
    tok = patchify(x, CFG)
    assert tok.shape == (2, CFG.tokens, CFG.patch_dim)
    back = unpatchify(tok, CFG)
    np.testing.assert_allclose(back, x, rtol=0, atol=0)


def test_timestep_embedding_distinct_and_bounded():
    t = jnp.asarray([0, 1, 100, 249], jnp.int32)
    emb = np.asarray(timestep_embedding(t, CFG.freq_dim))
    assert emb.shape == (4, CFG.freq_dim)
    assert np.all(np.abs(emb) <= 1.0 + 1e-6)
    # rows pairwise distinct
    for i in range(4):
        for j in range(i + 1, 4):
            assert not np.allclose(emb[i], emb[j])


def test_collect_covers_every_site():
    params = init_params(jax.random.PRNGKey(2), CFG)
    x, t, y = tiny_inputs()
    _, aux = forward_aux(params, x, t, y, CFG, collect=True)
    for layer in build_layers(CFG):
        for site in layer.sites:
            assert site.name in aux["in"], site.name


def test_delta_injection_shifts_output():
    """Injecting a delta at a layer's pre-activation output changes the
    prediction — the mechanism jax.grad differentiates for the Fisher."""
    params = init_params(jax.random.PRNGKey(4), CFG)
    x, t, y = tiny_inputs()
    shapes = layer_z_shapes(CFG, 2)
    base, _ = forward_aux(params, x, t, y, CFG)
    deltas = {k: jnp.zeros(s, jnp.float32) for k, s in shapes.items()}
    deltas["final"] = deltas["final"] + 0.1
    shifted, _ = forward_aux(params, x, t, y, CFG, deltas=deltas)
    assert float(jnp.max(jnp.abs(shifted - base))) > 1e-3


def test_grad_wrt_deltas_nonzero():
    params = init_params(jax.random.PRNGKey(5), CFG)
    x, t, y = tiny_inputs()
    eps_true = jnp.zeros_like(x)
    shapes = layer_z_shapes(CFG, 2)
    deltas0 = {k: jnp.zeros(s, jnp.float32) for k, s in shapes.items()}

    def loss_of(d):
        pred, _ = forward_aux(params, x, t, y, CFG, deltas=d)
        return jnp.mean((pred - eps_true) ** 2)

    grads = jax.grad(loss_of)(deltas0)
    # final layer always receives gradient; deep blocks may be gated
    assert float(jnp.max(jnp.abs(grads["final"]))) > 0.0
    assert set(grads.keys()) == set(shapes.keys())


def test_qparam_layout_stride_and_coverage():
    offsets, qp_len = qparam_layout(CFG)
    sites = [s.name for l in build_layers(CFG) for s in l.sites]
    assert set(offsets.keys()) == set(sites)
    offs = sorted(offsets.values())
    assert offs == list(range(0, qp_len, QP_STRIDE))


def test_layer_z_shapes_match_forward_aux():
    params = init_params(jax.random.PRNGKey(6), CFG)
    x, t, y = tiny_inputs()
    shapes = layer_z_shapes(CFG, 2)
    deltas = {k: jnp.zeros(s, jnp.float32) for k, s in shapes.items()}
    # shape mismatch would raise inside the forward
    out, _ = forward_aux(params, x, t, y, CFG, deltas=deltas)
    assert out.shape == x.shape
