"""Synthetic dataset, training utilities and metric networks."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import data as data_mod
from compile import features as feat_mod
from compile import train as train_mod
from compile.config import DIFFUSION, MODEL
from compile.model import init_params

jax.config.update("jax_platform_name", "cpu")

CFG = MODEL
DC = DIFFUSION


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------

def test_images_in_range_and_shaped():
    rng = np.random.default_rng(0)
    img, y = data_mod.sample_batch(rng, 16, CFG)
    assert img.shape == (16, CFG.img_size, CFG.img_size, CFG.channels)
    assert img.min() >= -1.0 and img.max() <= 1.0
    assert y.shape == (16,)
    assert y.min() >= 0 and y.max() < CFG.num_classes


def test_class_params_deterministic_and_distinct():
    p1 = data_mod.class_params(3, CFG.num_classes)
    p2 = data_mod.class_params(3, CFG.num_classes)
    assert np.allclose(p1[3], p2[3])
    # different classes → different geometry
    q = data_mod.class_params(4, CFG.num_classes)
    assert not np.allclose(p1[3], q[3]) or p1[0] != q[0]


def test_classes_are_visually_distinct():
    """Mean images of different classes differ a lot more than two mean
    images of the same class — the IS classifier's learnability basis."""
    rng = np.random.default_rng(1)
    means = []
    for k in range(4):
        labels = np.full((32,), k)
        img = data_mod.make_batch(rng, labels, CFG)
        means.append(img.mean(axis=0))
    for i in range(4):
        for j in range(i + 1, 4):
            d = np.abs(means[i] - means[j]).mean()
            assert d > 0.05, f"classes {i},{j} too similar ({d})"


# ---------------------------------------------------------------------------
# train
# ---------------------------------------------------------------------------

def test_alpha_bars_monotone():
    ab = train_mod.alpha_bars(DC)
    assert ab.shape == (DC.train_steps,)
    assert np.all(np.diff(ab) < 0)
    assert 0 < ab[-1] < ab[0] < 1


def test_q_sample_endpoints():
    rng = np.random.default_rng(2)
    x0 = jnp.asarray(rng.standard_normal((2, 16, 16, 3)), jnp.float32)
    eps = jnp.asarray(rng.standard_normal((2, 16, 16, 3)), jnp.float32)
    abar = jnp.asarray(train_mod.alpha_bars(DC), jnp.float32)
    x_lo = train_mod.q_sample(x0, jnp.asarray([0, 0]), eps, abar)
    # t=0: nearly clean signal
    assert float(jnp.mean((x_lo - x0) ** 2)) < 0.05
    x_hi = train_mod.q_sample(x0, jnp.asarray([DC.train_steps - 1] * 2),
                              eps, abar)
    # t=T-1: mostly noise
    assert float(jnp.mean((x_hi - eps) ** 2)) < 0.5


def test_train_step_reduces_loss():
    params = init_params(jax.random.PRNGKey(0), CFG)
    m, v = train_mod.adam_init(params)
    abar = jnp.asarray(train_mod.alpha_bars(DC), jnp.float32)
    rng = np.random.default_rng(3)

    losses = []
    step_fn = jax.jit(lambda p, mm, vv, s, x0, t, y, e: train_mod.train_step(
        p, mm, vv, s, x0, t, y, e, abar, CFG))
    # fixed batch → loss must drop when repeatedly stepped on it
    x0, y = data_mod.sample_batch(rng, 32, CFG)
    t = rng.integers(0, DC.train_steps, size=(32,))
    eps = rng.standard_normal(x0.shape).astype(np.float32)
    args = (jnp.asarray(x0), jnp.asarray(t, jnp.int32), jnp.asarray(y),
            jnp.asarray(eps))
    for s in range(20):
        params, m, v, loss = step_fn(params, m, v,
                                     jnp.asarray(s, jnp.int32), *args)
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.9, losses[:3] + losses[-3:]


def test_flatten_roundtrip():
    params = init_params(jax.random.PRNGKey(1), CFG)
    flat = train_mod.flatten_params(params, CFG)
    back = train_mod.unflatten_params(flat, CFG)
    assert set(back.keys()) == set(params.keys())
    for k in params:
        np.testing.assert_array_equal(np.asarray(back[k]),
                                      np.asarray(params[k]))


# ---------------------------------------------------------------------------
# features
# ---------------------------------------------------------------------------

def test_feature_net_shapes():
    fp = feat_mod.feature_params()
    rng = np.random.default_rng(4)
    img = jnp.asarray(rng.uniform(-1, 1, (8, 16, 16, 3)), jnp.float32)
    f, s = feat_mod.feature_net(fp, img)
    assert f.shape == (8, feat_mod.FEAT_DIM)
    assert s.shape == (8, feat_mod.SPAT_DIM)
    assert bool(jnp.all(jnp.isfinite(f)))


def test_feature_net_separates_distributions():
    """Real synthetic images vs pure noise produce distinct feature
    means — FID's discriminative basis on this substrate."""
    fp = feat_mod.feature_params()
    rng = np.random.default_rng(5)
    real, _ = data_mod.sample_batch(rng, 64, CFG)
    noise = rng.uniform(-1, 1, real.shape).astype(np.float32)
    f_real, _ = feat_mod.feature_net(fp, jnp.asarray(real))
    f_noise, _ = feat_mod.feature_net(fp, jnp.asarray(noise))
    d = float(jnp.linalg.norm(jnp.mean(f_real, 0) - jnp.mean(f_noise, 0)))
    assert d > 0.1, d


def test_classifier_trains_above_chance():
    cp, acc = feat_mod.train_classifier(CFG, steps=60, batch=64)
    assert acc > 2.0 / CFG.num_classes, acc


def test_classifier_logits_shape():
    cp = feat_mod.classifier_init(CFG)
    rng = np.random.default_rng(6)
    img = jnp.asarray(rng.uniform(-1, 1, (5, 16, 16, 3)), jnp.float32)
    logits = feat_mod.classifier_logits(cp, img)
    assert logits.shape == (5, CFG.num_classes)
