"""L1 correctness: every pallas kernel against its pure-jnp oracle.

Hypothesis sweeps shapes, dtypes-range and quantization parameters;
assert_allclose against ``kernels.ref``. This is the build-time gate —
the AOT artifact embeds the pallas lowering, so equality here certifies
the whole quantized model graph (test_qmodel covers the composition).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (fakequant_uniform, mrq_gelu, mrq_softmax,
                             qmatmul)
from compile.kernels import ref
from compile.kernels.quant import _pick_rows

jax.config.update("jax_platform_name", "cpu")


def uniform_qp(bits: int, lo: float, hi: float) -> np.ndarray:
    levels = float(2 ** bits - 1)
    s = max(hi - lo, 1e-6) / levels
    z = round(-lo / s)
    return np.array([s, z, levels, 0.0], np.float32)


def softmax_qp(bits: int, s1: float) -> np.ndarray:
    half = float(2 ** (bits - 1))
    return np.array([s1, half, 0.0, 0.0], np.float32)


def gelu_qp(bits: int, s1: float, s2: float) -> np.ndarray:
    half = float(2 ** (bits - 1))
    return np.array([s1, s2, half, 0.0], np.float32)


BYPASS = np.zeros(4, np.float32)

dims = st.integers(min_value=1, max_value=33)
bits = st.sampled_from([4, 6, 8])
seeds = st.integers(min_value=0, max_value=2 ** 31 - 1)


# ---------------------------------------------------------------------------
# fakequant_uniform
# ---------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(r=dims, c=dims, b=bits, seed=seeds)
def test_fakequant_matches_ref(r, c, b, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((r, c)), jnp.float32)
    qp = uniform_qp(b, float(x.min()), float(x.max()))
    got = fakequant_uniform(x, jnp.asarray(qp))
    want = ref.fakequant_uniform_ref(x, jnp.asarray(qp))
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(r=dims, c=dims, seed=seeds)
def test_fakequant_bypass_is_identity(r, c, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((r, c)), jnp.float32)
    got = fakequant_uniform(x, jnp.asarray(BYPASS))
    np.testing.assert_allclose(got, x, rtol=0, atol=0)


def test_fakequant_3d_shape_preserved():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((2, 5, 7)), jnp.float32)
    qp = uniform_qp(8, -3.0, 3.0)
    got = fakequant_uniform(x, jnp.asarray(qp))
    assert got.shape == x.shape
    want = ref.fakequant_uniform_ref(x, jnp.asarray(qp))
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(b=bits, seed=seeds)
def test_fakequant_error_bounded_by_half_step(b, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.uniform(-1, 1, size=(16, 16)), jnp.float32)
    qp = uniform_qp(b, -1.0, 1.0)
    got = np.asarray(fakequant_uniform(x, jnp.asarray(qp)))
    assert np.max(np.abs(got - np.asarray(x))) <= qp[0] * 0.5 + 1e-6


# ---------------------------------------------------------------------------
# mrq_softmax
# ---------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(r=dims, c=dims, b=bits, seed=seeds,
       s1=st.floats(min_value=1e-5, max_value=0.05))
def test_mrq_softmax_matches_ref(r, c, b, seed, s1):
    rng = np.random.default_rng(seed)
    logits = jnp.asarray(4.0 * rng.standard_normal((r, c)), jnp.float32)
    qp = jnp.asarray(softmax_qp(b, s1))
    got = mrq_softmax(logits, qp)
    want = ref.mrq_softmax_ref(logits, qp)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(r=dims, c=dims, seed=seeds)
def test_mrq_softmax_bypass_is_plain_softmax(r, c, seed):
    rng = np.random.default_rng(seed)
    logits = jnp.asarray(rng.standard_normal((r, c)), jnp.float32)
    got = mrq_softmax(logits, jnp.asarray(BYPASS))
    want = jax.nn.softmax(logits, axis=-1)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


def test_mrq_softmax_output_in_unit_interval():
    rng = np.random.default_rng(3)
    logits = jnp.asarray(8 * rng.standard_normal((32, 17)), jnp.float32)
    qp = jnp.asarray(softmax_qp(6, 0.001))
    got = np.asarray(mrq_softmax(logits, qp))
    assert got.min() >= 0.0 and got.max() <= 1.0 + 1e-6


def test_mrq_softmax_4d_attention_shape():
    rng = np.random.default_rng(4)
    logits = jnp.asarray(rng.standard_normal((2, 4, 8, 8)), jnp.float32)
    qp = jnp.asarray(softmax_qp(8, 0.003))
    got = mrq_softmax(logits, qp)
    want = ref.mrq_softmax_ref(logits, qp)
    assert got.shape == (2, 4, 8, 8)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# mrq_gelu
# ---------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(r=dims, c=dims, b=bits, seed=seeds,
       s1=st.floats(min_value=1e-4, max_value=0.05),
       s2=st.floats(min_value=1e-3, max_value=0.2))
def test_mrq_gelu_matches_ref(r, c, b, seed, s1, s2):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(3.0 * rng.standard_normal((r, c)), jnp.float32)
    qp = jnp.asarray(gelu_qp(b, s1, s2))
    got = mrq_gelu(x, qp)
    want = ref.mrq_gelu_ref(x, qp)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(r=dims, c=dims, seed=seeds)
def test_mrq_gelu_bypass_is_plain_gelu(r, c, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((r, c)), jnp.float32)
    got = mrq_gelu(x, jnp.asarray(np.zeros(4, np.float32)))
    want = ref.gelu_ref(x)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


def test_mrq_gelu_preserves_sign_regions():
    x = jnp.asarray(np.linspace(-4, 4, 97, dtype=np.float32).reshape(1, -1))
    qp = jnp.asarray(gelu_qp(8, 0.005, 0.05))
    got = np.asarray(mrq_gelu(x, qp))[0]
    g = np.asarray(ref.gelu_ref(x))[0]
    assert np.all(got[g < 0] <= 0.0 + 1e-7)
    assert np.all(got[g >= 0] >= 0.0 - 1e-7)


# ---------------------------------------------------------------------------
# qmatmul
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(g=st.integers(1, 4), m=dims, k=st.integers(1, 16),
       n=st.integers(1, 16), b=bits, seed=seeds)
def test_qmatmul_matches_ref(g, m, k, n, b, seed):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.standard_normal((g, m, k)), jnp.float32)
    bb = jnp.asarray(rng.standard_normal((g, k, n)), jnp.float32)
    qpa = jnp.asarray(uniform_qp(b, -3.0, 3.0))
    qpb = jnp.asarray(uniform_qp(b, -3.0, 3.0))
    got = qmatmul(a, bb, qpa, qpb)
    want = ref.qmatmul_ref(a, bb, qpa, qpb)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_qmatmul_bypass_equals_plain_matmul():
    rng = np.random.default_rng(9)
    a = jnp.asarray(rng.standard_normal((3, 8, 5)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((3, 5, 7)), jnp.float32)
    byp = jnp.asarray(BYPASS)
    got = qmatmul(a, b, byp, byp)
    want = jnp.einsum("gmk,gkn->gmn", a, b)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_qmatmul_mixed_bypass():
    # A bypassed (already MRQ-quantized upstream), B quantized — the AV
    # configuration in the quantized model.
    rng = np.random.default_rng(10)
    a = jnp.asarray(rng.uniform(0, 1, (2, 6, 6)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((2, 6, 4)), jnp.float32)
    qpb = jnp.asarray(uniform_qp(8, -3.0, 3.0))
    got = qmatmul(a, b, jnp.asarray(BYPASS), qpb)
    want = jnp.einsum("gmk,gkn->gmn", a,
                      ref.fakequant_uniform_ref(b, qpb))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# block-shape helper
# ---------------------------------------------------------------------------

@settings(max_examples=60, deadline=None)
@given(rows=st.integers(1, 4096))
def test_pick_rows_divides_and_bounds(rows):
    br = _pick_rows(rows)
    assert rows % br == 0
    assert 1 <= br <= 256


def test_pick_rows_prefers_large_blocks():
    assert _pick_rows(1024) == 256
    assert _pick_rows(256) == 256
    assert _pick_rows(17) == 17   # prime ≤ 256 → itself
