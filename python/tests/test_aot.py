"""AOT contract tests: manifest layout vs the rust loader's assumptions,
HLO-text lowering sanity, and (when artifacts exist) on-disk consistency.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile.config import (CALIB_BATCH, MODEL, QP_STRIDE, build_layers,
                            qparam_layout)
from compile.model import param_specs

jax.config.update("jax_platform_name", "cpu")

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_hlo_text_export_roundtrip(tmp_path):
    """A tiny jitted fn lowers to parseable HLO text via the same path
    aot.py uses for the real artifacts."""
    def f(a, b):
        return (jnp.dot(a, b) + 1.0,)

    spec = [jax.ShapeDtypeStruct((4, 4), jnp.float32)] * 2
    text = aot.to_hlo_text(jax.jit(f).lower(*spec))
    assert "HloModule" in text
    assert "f32[4,4]" in text
    p = tmp_path / "t.hlo.txt"
    p.write_text(text)
    assert p.stat().st_size > 100


def test_in_shape_covers_every_site():
    layers = build_layers(MODEL)
    for layer in layers:
        for site in layer.sites:
            shape = aot._in_shape(site.name, MODEL, CALIB_BATCH)
            assert all(d > 0 for d in shape), site.name


def test_in_shape_matches_model_dims():
    B = CALIB_BATCH
    assert aot._in_shape("patch_embed.x", MODEL, B) == \
        (B, MODEL.tokens, MODEL.patch_dim)
    assert aot._in_shape("blk0.qk.a", MODEL, B) == \
        (B, MODEL.heads, MODEL.tokens, MODEL.head_dim)
    assert aot._in_shape("blk1.av.a", MODEL, B) == \
        (B, MODEL.heads, MODEL.tokens, MODEL.tokens)
    assert aot._in_shape("blk2.fc2.x", MODEL, B) == \
        (B, MODEL.tokens, MODEL.mlp_dim)


def test_mrq_sites_are_where_the_paper_puts_them():
    layers = build_layers(MODEL)
    softmax_sites = [s for l in layers for s in l.sites
                     if s.kind == "mrq_softmax"]
    gelu_sites = [s for l in layers for s in l.sites if s.kind == "mrq_gelu"]
    assert len(softmax_sites) == MODEL.depth
    assert len(gelu_sites) == MODEL.depth
    assert all(s.tgq for s in softmax_sites)       # TGQ on post-softmax
    assert not any(s.tgq for s in gelu_sites)      # not on post-GELU
    assert all(".av.a" in s.name for s in softmax_sites)
    assert all(".fc2.x" in s.name for s in gelu_sites)


# ---------------------------------------------------------------------------
# on-disk artifact consistency (skipped until `make artifacts` has run)
# ---------------------------------------------------------------------------

needs_artifacts = pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="artifacts not built")


@needs_artifacts
def test_manifest_matches_config():
    with open(os.path.join(ART, "manifest.json")) as f:
        man = json.load(f)
    assert man["model"]["dim"] == MODEL.dim
    assert man["model"]["depth"] == MODEL.depth
    assert man["model"]["tokens"] == MODEL.tokens
    offsets, qp_len = qparam_layout(MODEL)
    assert man["qp_len"] == qp_len
    man_sites = {s["name"]: s["qp_offset"]
                 for l in man["layers"] for s in l["sites"]}
    assert man_sites == offsets


@needs_artifacts
def test_weights_bin_size_matches_specs():
    size = os.path.getsize(os.path.join(ART, "weights.bin"))
    total = sum(int(np.prod(s)) for _, s in param_specs(MODEL))
    assert size == total * 4


@needs_artifacts
def test_all_artifacts_exist_and_nonempty():
    with open(os.path.join(ART, "manifest.json")) as f:
        man = json.load(f)
    for name, fname in man["artifacts"].items():
        p = os.path.join(ART, fname)
        assert os.path.exists(p), name
        assert os.path.getsize(p) > 1000, name
        with open(p) as fh:
            head = fh.read(200)
        assert "HloModule" in head, name


@needs_artifacts
def test_fid_ref_size():
    with open(os.path.join(ART, "manifest.json")) as f:
        man = json.load(f)
    fd, sd = man["feat_dim"], man["spat_dim"]
    size = os.path.getsize(os.path.join(ART, man["fid_ref"]))
    assert size == (fd + fd * fd + sd + sd * sd) * 4


@needs_artifacts
def test_capture_output_count():
    with open(os.path.join(ART, "manifest.json")) as f:
        man = json.load(f)
    layers = build_layers(MODEL)
    expect = sum(
        (1 if l.ltype == "linear" else 2) + 1 for l in layers)
    assert len(man["capture_outputs"]) == expect


@needs_artifacts
def test_qp_offsets_stride_aligned():
    with open(os.path.join(ART, "manifest.json")) as f:
        man = json.load(f)
    offs = sorted(s["qp_offset"] for l in man["layers"] for s in l["sites"])
    assert offs == list(range(0, man["qp_len"], QP_STRIDE))
