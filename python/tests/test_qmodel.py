"""Quantized-forward composition tests: pallas path vs oracle path vs FP.

The AOT `dit_quant` artifact lowers `forward_quant` with PALLAS_OPS;
equality with REF_OPS here, plus the per-kernel sweeps in test_kernels,
certifies the shipped graph end to end.
"""

import jax
import jax.numpy as jnp
import numpy as np

from compile.config import MODEL, QP_STRIDE, build_layers, qparam_layout
from compile.model import forward, init_params
from compile.qmodel import forward_quant, forward_quant_ref

jax.config.update("jax_platform_name", "cpu")

CFG = MODEL


def inputs(b=2, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(
        (b, CFG.img_size, CFG.img_size, CFG.channels)), jnp.float32)
    t = jnp.asarray(rng.integers(0, 250, size=(b,)), jnp.int32)
    y = jnp.asarray(rng.integers(0, CFG.num_classes, size=(b,)), jnp.int32)
    return x, t, y


def bypass_qparams():
    _, qp_len = qparam_layout(CFG)
    return jnp.zeros((qp_len,), jnp.float32)


def w8a8ish_qparams(seed=1):
    """A plausible fully-quantized parameter vector (8-bit everywhere)."""
    offsets, qp_len = qparam_layout(CFG)
    qp = np.zeros(qp_len, np.float32)
    for layer in build_layers(CFG):
        for site in layer.sites:
            off = offsets[site.name]
            if site.kind == "uniform":
                qp[off:off + QP_STRIDE] = [6.0 / 255.0, 128.0, 255.0, 0.0]
            elif site.kind == "mrq_softmax":
                qp[off:off + QP_STRIDE] = [1.0 / (128.0 * 128.0), 128.0,
                                           0.0, 0.0]
            else:  # mrq_gelu
                qp[off:off + QP_STRIDE] = [0.002, 0.03, 128.0, 0.0]
    return jnp.asarray(qp)


def test_bypass_qparams_reproduce_fp_forward():
    params = init_params(jax.random.PRNGKey(0), CFG)
    x, t, y = inputs()
    fp = forward(params, x, t, y, CFG)
    q = forward_quant(params, x, t, y, bypass_qparams(), CFG)
    np.testing.assert_allclose(np.asarray(q), np.asarray(fp),
                               rtol=1e-5, atol=1e-5)


def test_pallas_and_ref_paths_agree_bypass():
    params = init_params(jax.random.PRNGKey(1), CFG)
    x, t, y = inputs(seed=2)
    qp = bypass_qparams()
    a = forward_quant(params, x, t, y, qp, CFG)
    b = forward_quant_ref(params, x, t, y, qp, CFG)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-5)


def test_pallas_and_ref_paths_agree_quantized():
    params = init_params(jax.random.PRNGKey(2), CFG)
    x, t, y = inputs(seed=3)
    qp = w8a8ish_qparams()
    a = forward_quant(params, x, t, y, qp, CFG)
    b = forward_quant_ref(params, x, t, y, qp, CFG)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-4, atol=1e-4)


def test_quantization_perturbs_but_stays_finite():
    params = init_params(jax.random.PRNGKey(3), CFG)
    x, t, y = inputs(seed=4)
    fp = forward_quant(params, x, t, y, bypass_qparams(), CFG)
    q = forward_quant(params, x, t, y, w8a8ish_qparams(), CFG)
    diff = float(jnp.max(jnp.abs(q - fp)))
    assert diff > 0.0
    assert bool(jnp.all(jnp.isfinite(q)))


def test_single_site_bypass_isolation():
    """Quantizing only ONE site changes the output; zeroing that site's
    slot restores FP — the mechanism the rust ablations rely on."""
    params = init_params(jax.random.PRNGKey(4), CFG)
    x, t, y = inputs(seed=5)
    # NOTE: at adaLN-Zero init the block gates are 0, so block-internal
    # sites cannot reach the output of an *untrained* model; use the
    # patch-embedding site, which is always on the residual path.
    offsets, qp_len = qparam_layout(CFG)
    qp = np.zeros(qp_len, np.float32)
    off = offsets["patch_embed.x"]
    qp[off:off + QP_STRIDE] = [0.5, 8.0, 15.0, 0.0]  # crude 4-bit
    fp = forward_quant(params, x, t, y, jnp.zeros(qp_len, jnp.float32), CFG)
    q = forward_quant(params, x, t, y, jnp.asarray(qp), CFG)
    assert float(jnp.max(jnp.abs(q - fp))) > 1e-6


def test_coarser_bits_increase_output_error():
    params = init_params(jax.random.PRNGKey(5), CFG)
    x, t, y = inputs(seed=6)
    offsets, qp_len = qparam_layout(CFG)
    fp = forward_quant(params, x, t, y, jnp.zeros(qp_len, jnp.float32), CFG)

    def uniform_all(bits):
        levels = float(2 ** bits - 1)
        qp = np.zeros(qp_len, np.float32)
        for layer in build_layers(CFG):
            for site in layer.sites:
                off = offsets[site.name]
                if site.kind == "uniform":
                    qp[off:off + QP_STRIDE] = [6.0 / levels,
                                               round(levels / 2), levels, 0]
        return jnp.asarray(qp)

    e8 = float(jnp.mean((forward_quant(params, x, t, y, uniform_all(8),
                                       CFG) - fp) ** 2))
    e4 = float(jnp.mean((forward_quant(params, x, t, y, uniform_all(4),
                                       CFG) - fp) ** 2))
    assert e4 > e8 > 0.0
